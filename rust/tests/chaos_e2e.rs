//! Wire-level chaos tier (hermetic — no network, no PJRT): deterministic
//! transport fault injection across **both** socket control planes, the
//! proc-fleet coordinator↔worker sockets (`src/pool`) and the mpqd
//! client↔daemon socket (`src/serve`).
//!
//! Contracts under test (ISSUE 10 acceptance):
//!
//! * **Every single-clause wire fault** (`wdrop`/`wcorrupt`/`wsplit`/
//!   `wreset`/`wdelay`) injected at the framing seam heals through the
//!   existing supervision machinery — respawn, replay, requeue, collect
//!   deadline — and the Phase-1 sweep stays **byte-equal** to the serial
//!   oracle.  Death reasons name the injected fault.
//! * **Randomized schedules** (`wseed:S`): byte-equal results or a typed
//!   error naming the injected fault.  Never a hang — every scenario runs
//!   under a hard watchdog timeout.
//! * **Heartbeats**: a SIGSTOPped worker answers nothing; the liveness
//!   deadline (no frame within the window) converts the frozen peer into
//!   a death notice and a respawn with no fault plan at all.
//! * **Client retry + idempotency**: corrupted/dropped daemon replies are
//!   absorbed by bounded exponential backoff under an idempotency key —
//!   one admission, never a duplicate; a retried submit after a daemon
//!   kill resumes the kept journal and **never re-executes completed
//!   barriers** (`replayed == N` asserted).
//! * **Overload + deadlines**: past `max_jobs` the daemon sheds with a
//!   typed `RETRY_AFTER`; per-job `deadline_ms` cancels gracefully at a
//!   phase boundary, keeps the journal, and an idem-keyed resubmit
//!   revives the same job and replays it.
//! * **No strands**: chaos runs leave no `job_*` journals or temp files.

use mpq::coordinator::Pipeline;
use mpq::groups::Lattice;
use mpq::pool::{EvalFleet, FaultPlan};
use mpq::sensitivity::SensEntry;
use mpq::serve::daemon::{self, ServeCfg};
use mpq::serve::{run_local, Client, JobPolicy};
use mpq::sim::{self, SimSpec};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

const MODEL: &str = "sim_mlp";

/// Once per process: point proc fleets at this build's own `mpq` binary
/// and shorten the heartbeat so liveness deaths fire within test budgets
/// (liveness window = `max(8·hb, 1000)` ms — still 1 s here).
fn chaos_env() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        std::env::set_var("MPQ_WORKER_BIN", env!("CARGO_BIN_EXE_mpq"));
        std::env::set_var("MPQ_HEARTBEAT_MS", "50");
    });
}

/// Fresh sim artifacts under a per-test temp dir.
fn sim_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_chaos_e2e_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    sim::generate(&dir, &SimSpec::default()).expect("generate sim artifacts");
    dir
}

/// Serial-oracle Phase-1 sweep (no fleet attached).
fn serial_sens(dir: &Path) -> Vec<SensEntry> {
    let mut p = Pipeline::open(dir, MODEL).expect("open sim_mlp");
    p.calibrate(128, 0).expect("calibrate");
    p.sensitivity_sqnr(&Lattice::practical()).expect("serial sweep")
}

/// Two Phase-1 lists agree in order and **bit-for-bit** scores.
fn assert_sens_bits(got: &[SensEntry], want: &[SensEntry], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: list length");
    for (a, b) in got.iter().zip(want) {
        assert_eq!((a.group, a.cand), (b.group, b.cand), "{tag}: order diverged");
        assert_eq!(
            a.score.to_bits(),
            b.score.to_bits(),
            "{tag}: score for (g{}, {:?}): {} vs {}",
            a.group,
            a.cand,
            a.score,
            b.score
        );
    }
}

/// Zero-hangs guarantee, enforced: every chaos scenario runs on its own
/// thread under a hard watchdog.  A scenario that outlives `secs` fails
/// the test instead of wedging the suite (fleets are `!Send`, so the
/// scenario builds everything inside the thread and ships plain data out).
fn run_with_timeout<T: Send + 'static>(
    tag: &str,
    secs: u64,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let h = thread::Builder::new()
        .name(format!("chaos-{tag}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn chaos scenario thread");
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => {
            h.join().expect("scenario thread died after reporting");
            v
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => match h.join() {
            Err(p) => std::panic::resume_unwind(p),
            Ok(()) => unreachable!("scenario thread exited without a result"),
        },
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("{tag}: scenario hung past {secs}s — liveness violated")
        }
    }
}

// ---------------------------------------------------------------------------
// proc-fleet plane
// ---------------------------------------------------------------------------

/// Tentpole, clause by clause: each wire-fault kind fires exactly once on
/// a real worker socket and the sweep still comes back byte-equal.  The
/// mangling clauses must turn into a worker death whose reason names the
/// injected fault; a delayed frame must never count as one.
#[test]
fn every_wire_fault_clause_heals_to_byte_equal_results() {
    chaos_env();
    let dir = sim_dir("clauses");
    let serial = serial_sens(&dir);

    for clause in ["wdrop@1:3", "wcorrupt@1:3", "wsplit@1:3", "wreset@1:3", "wdelay@1:40"] {
        // the collect deadline is the net under a silently dropped JOB
        // frame (nothing errors — the reply just never comes); backoff:0
        // keeps respawns instant
        let spec = format!("{clause},deadline:2000,backoff:0");
        let (sens, fs, wc) = {
            let dir = dir.clone();
            run_with_timeout(clause, 300, move || {
                let plan = FaultPlan::parse(&spec).expect("parse wire plan");
                let fleet = EvalFleet::with_faults_proc(&dir, 2, plan).expect("proc fleet");
                let mut p = Pipeline::open(&dir, MODEL).unwrap();
                p.attach_fleet(&fleet).unwrap();
                p.calibrate(128, 0).unwrap();
                let sens = p.sensitivity_sqnr(&Lattice::practical()).unwrap();
                (sens, fleet.failure_stats(), fleet.wire_counters())
            })
        };
        assert_sens_bits(&sens, &serial, clause);
        match clause.split('@').next().unwrap() {
            "wdelay" => {
                assert!(wc.frames_delayed >= 1, "{clause}: no frame was delayed: {wc:?}");
                assert_eq!(wc.injected(), 0, "{clause}: a delay is not an injected mangle");
                assert_eq!(fs.worker_restarts, 0, "{clause}: a delay is not a death: {fs:?}");
            }
            "wdrop" => {
                // a dropped frame heals silently (a lost PING) or through
                // the collect deadline (a lost JOB) — either way the sweep
                // above already came back byte-equal
                assert_eq!(wc.injected(), 1, "{clause}: one-shot fault count: {wc:?}");
            }
            _ => {
                assert_eq!(wc.injected(), 1, "{clause}: one-shot fault count: {wc:?}");
                assert!(
                    fs.worker_restarts >= 1,
                    "{clause}: a mangled frame must kill and respawn the lane: {fs:?}"
                );
                assert!(
                    fs.last_deaths.iter().any(|d| d.contains("injected fault")),
                    "{clause}: death reason must name the injected fault: {:?}",
                    fs.last_deaths
                );
            }
        }
    }
}

/// Randomized multi-clause schedules: `wseed:S` derives a per-lane fault
/// schedule (deterministic in `(seed, lane)`, pinned by `property.rs`).
/// Every seed must end in byte-equal results or a typed error naming the
/// injected fault — and never, ever a hang.
#[test]
fn randomized_wire_schedules_heal_or_name_the_injected_fault() {
    chaos_env();
    let dir = sim_dir("wseed");
    let serial = serial_sens(&dir);

    for seed in 0..4u64 {
        let tag = format!("wseed:{seed}");
        let (run, wc) = {
            let dir = dir.clone();
            run_with_timeout(&tag, 300, move || {
                let plan = FaultPlan::parse(&format!("wseed:{seed},backoff:0")).unwrap();
                assert_eq!(plan.deadline_ms, Some(2000), "wseed must imply a collect deadline");
                let fleet = match EvalFleet::with_faults_proc(&dir, 3, plan) {
                    Ok(f) => f,
                    Err(e) => return (Err(format!("{e:#}")), None),
                };
                let run = (|| -> anyhow::Result<Vec<SensEntry>> {
                    let mut p = Pipeline::open(&dir, MODEL)?;
                    p.attach_fleet(&fleet)?;
                    p.calibrate(128, 0)?;
                    p.sensitivity_sqnr(&Lattice::practical())
                })()
                .map_err(|e| format!("{e:#}"));
                (run, Some(fleet.wire_counters()))
            })
        };
        match run {
            Ok(sens) => assert_sens_bits(&sens, &serial, &tag),
            Err(msg) => {
                assert!(
                    msg.contains("injected fault"),
                    "{tag}: typed error must name the injected fault: {msg}"
                );
                assert!(
                    wc.is_none() || wc.unwrap().injected() > 0,
                    "{tag}: error without an injected fault on the books: {wc:?}"
                );
            }
        }
    }
}

/// The heartbeat guarantee, with **no fault plan at all**: a SIGSTOPped
/// worker holds its socket open but answers nothing — only the liveness
/// deadline (no frame within the window, PONGs included) can tell it from
/// a slow peer.  The frozen lane becomes a death notice naming the missed
/// heartbeat, the supervisor respawns it, and sweeps stay byte-equal.
#[test]
fn frozen_worker_trips_the_liveness_deadline_and_is_respawned() {
    chaos_env();
    let dir = sim_dir("sigstop");
    let serial = serial_sens(&dir);

    let (sens, again, fs, wc) = {
        let dir = dir.clone();
        run_with_timeout("sigstop", 300, move || {
            let fleet = EvalFleet::new_proc(&dir, 2).unwrap();
            let mut p = Pipeline::open(&dir, MODEL).unwrap();
            p.attach_fleet(&fleet).unwrap();
            p.calibrate(128, 0).unwrap();

            let victim = fleet.proc_pids()[1].expect("lane 1 is process-backed");
            let status = std::process::Command::new("kill")
                .args(["-STOP", &victim.to_string()])
                .status()
                .expect("spawn kill");
            assert!(status.success(), "kill -STOP {victim} failed");

            let sens = p.sensitivity_sqnr(&Lattice::practical()).unwrap();
            let fs = fleet.failure_stats();
            let wc = fleet.wire_counters();
            // the healed fleet keeps serving fresh sweeps exactly
            p.clear_eval_memo();
            let again = p.sensitivity_sqnr(&Lattice::practical()).unwrap();
            (sens, again, fs, wc)
        })
    };
    assert_sens_bits(&sens, &serial, "sweep across a frozen worker");
    assert_sens_bits(&again, &serial, "re-sweep on the healed fleet");
    assert!(fs.worker_restarts >= 1, "the frozen lane must be respawned: {fs:?}");
    assert!(
        fs.last_deaths.iter().any(|d| d.contains("heartbeat missed")),
        "death reason must name the missed heartbeat: {:?}",
        fs.last_deaths
    );
    assert!(wc.heartbeats_sent > 0, "no pings flowed: {wc:?}");
    assert!(wc.heartbeat_deaths >= 1, "liveness deadline never fired: {wc:?}");
}

// ---------------------------------------------------------------------------
// mpqd serve plane
// ---------------------------------------------------------------------------

/// Two-model sim zoo under a per-test temp dir (generation is
/// deterministic: same specs → byte-identical artifacts).
fn zoo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_chaos_serve_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    let a = SimSpec {
        name: "srv_a".into(),
        batch: 4,
        dims: vec![8, 10, 6],
        calib_n: 32,
        val_n: 16,
        ood_n: 0,
        seed: 7,
        fault_plan: None,
    };
    let b = SimSpec { name: "srv_b".into(), dims: vec![8, 12, 6], seed: 11, ..a.clone() };
    sim::generate_zoo(&dir, &[a, b]).expect("generate sim zoo");
    dir
}

fn small_policy() -> JobPolicy {
    JobPolicy { calib_n: 16, adaround_steps: 4, ..Default::default() }
}

fn cfg(dir: &Path, sock: &Path, state: &Path) -> ServeCfg {
    ServeCfg {
        dir: dir.to_path_buf(),
        socket: sock.to_path_buf(),
        state_dir: state.to_path_buf(),
        workers: 2,
        max_idle: 2,
        max_jobs: 4,
        fault_plan: None,
        hold: false,
        io_timeout_ms: daemon::DEFAULT_IO_TIMEOUT_MS,
    }
}

fn spawn_daemon(cfg: ServeCfg) -> thread::JoinHandle<anyhow::Result<()>> {
    thread::spawn(move || daemon::run(cfg))
}

/// Connect without any probe round trip — chaos tests script the daemon's
/// per-connection fault lanes by connection order, so the first client
/// connection must stay connection 0.
fn dial_client(socket: &Path) -> Client {
    for _ in 0..1000 {
        if let Ok(c) = Client::connect(socket) {
            return c;
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("daemon on {} never became reachable", socket.display());
}

fn result_text(payload: &mpq::jsonio::Json) -> String {
    payload.req("result").unwrap().to_string()
}

fn durability(payload: &mpq::jsonio::Json, field: &str) -> u64 {
    payload.req("durability").unwrap().req(field).unwrap().as_f64().unwrap() as u64
}

fn wire_stat(status: &mpq::jsonio::Json, field: &str) -> u64 {
    status
        .req("telemetry")
        .unwrap()
        .req("wire")
        .unwrap()
        .req(field)
        .unwrap()
        .as_f64()
        .unwrap() as u64
}

fn assert_no_strands(state: &Path, tag: &str) {
    let stranded: Vec<String> = std::fs::read_dir(state)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".mpqj") || n.contains(".tmp."))
        .collect();
    assert!(stranded.is_empty(), "{tag}: stranded files: {stranded:?}");
}

/// Daemon replies are mangled on the wire (a corrupted submit ACK on
/// connection 0, then the retried submit's ACK dropped on connection 1)
/// and the client's bounded backoff + idempotency key absorb both: one
/// admission, one job, the correct durable result — and the daemon's
/// telemetry shows exactly what was injected and retried.
#[test]
fn daemon_replies_survive_injected_wire_faults_via_idempotent_retry() {
    let dir = zoo_dir("wire");
    let policy = small_policy();
    let base = run_local(&dir, "srv_a", &policy, 0, None).unwrap().to_string();

    let sock = dir.join("d.sock");
    let state = dir.join("mpqd");
    let mut dc = cfg(&dir, &sock, &state);
    dc.fault_plan = Some("wcorrupt@0:1,wdrop@1:1".into());
    let h = spawn_daemon(dc);

    let mut c = dial_client(&sock);
    let id = c.submit("srv_a", &policy).expect("submit must survive two mangled ACKs");
    let res = dial_client(&sock).watch(id, |_| {}).unwrap();
    assert_eq!(result_text(&res), base, "result after wire chaos differs from serial");

    let mut probe = dial_client(&sock);
    let st = probe.status().unwrap();
    assert_eq!(
        st.req("jobs").unwrap().as_arr().unwrap().len(),
        1,
        "retries admitted a duplicate job: {st}"
    );
    assert_eq!(wire_stat(&st, "frames_corrupted"), 1, "corrupt clause fired once");
    assert_eq!(wire_stat(&st, "frames_dropped"), 1, "drop clause fired once");
    assert!(
        wire_stat(&st, "retries") >= 2,
        "both resubmits should land as idempotency-key hits: {st}"
    );

    probe.shutdown().unwrap();
    h.join().unwrap().unwrap();
    assert!(!sock.exists(), "socket file left behind after shutdown");
    assert_no_strands(&state, "wire chaos");
}

/// The acceptance kill: a daemon dies mid-job (crash barrier on the run
/// journal), and a **new** client retries the submit under the same
/// idempotency key against the restarted daemon.  The retry maps to the
/// same job id, the kept journal replays exactly the `CRASH_AT` completed
/// barriers, and only the remainder is recomputed — byte-equal result.
#[test]
fn killed_daemon_retried_submit_never_reexecutes_completed_barriers() {
    const CRASH_AT: u64 = 5;
    const KEY: &str = "chaos-idem-crash";
    let dir = zoo_dir("idem");
    let policy = small_policy();
    let base = run_local(&dir, "srv_a", &policy, 0, None).unwrap().to_string();

    // clean daemon run first: learn the job's total barrier count
    let sock1 = dir.join("d1.sock");
    let h1 = spawn_daemon(cfg(&dir, &sock1, &dir.join("mpqd1")));
    let mut c1 = dial_client(&sock1);
    let id = c1.submit("srv_a", &policy).unwrap();
    let res = dial_client(&sock1).watch(id, |_| {}).unwrap();
    assert_eq!(result_text(&res), base);
    let total = durability(&res, "appended");
    assert!(total > CRASH_AT, "need more than {CRASH_AT} barriers, got {total}");
    c1.shutdown().unwrap();
    h1.join().unwrap().unwrap();

    // kill the daemon mid-job at journal barrier CRASH_AT
    let sock2 = dir.join("d2.sock");
    let state2 = dir.join("mpqd2");
    let mut crash_cfg = cfg(&dir, &sock2, &state2);
    crash_cfg.fault_plan = Some(format!("crash@PHASE:{CRASH_AT}"));
    let h2 = spawn_daemon(crash_cfg);
    let mut c2 = dial_client(&sock2);
    let jid = c2.submit_idem("srv_a", &policy, KEY).unwrap();
    let err = h2.join().expect_err("daemon survived its crash barrier");
    let msg = err
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| err.downcast_ref::<&str>().copied())
        .unwrap_or("<non-string panic>");
    assert!(msg.contains("crash@PHASE"), "unexpected panic: {msg}");
    assert!(
        state2.join(format!("job_{jid}.mpqj")).exists(),
        "job journal missing after the kill"
    );

    // restart; a brand-new client retries the same key
    let h3 = spawn_daemon(cfg(&dir, &sock2, &state2));
    let mut c3 = dial_client(&sock2);
    let again = c3.submit_idem("srv_a", &policy, KEY).unwrap();
    assert_eq!(again, jid, "retried submit admitted a duplicate job");
    let resumed = dial_client(&sock2).watch(jid, |_| {}).unwrap();
    assert_eq!(result_text(&resumed), base, "resumed result differs from serial");
    assert_eq!(durability(&resumed, "replayed"), CRASH_AT, "replayed unit count");
    assert_eq!(
        durability(&resumed, "appended"),
        total - CRASH_AT,
        "completed units were re-executed after restart"
    );
    let st = c3.status().unwrap();
    assert!(wire_stat(&st, "retries") >= 1, "idem hit must count as a retry: {st}");

    c3.shutdown().unwrap();
    h3.join().unwrap().unwrap();
    assert_no_strands(&state2, "crash + idem retry");
}

/// Overload shedding: past the `max_jobs` cap the daemon answers with a
/// typed `RETRY_AFTER` instead of an ERR; the client backs off, retries,
/// and finally surfaces a typed shed error once its budget is spent.
/// Freeing the slot lets the very same submit land.
#[test]
fn overloaded_daemon_sheds_with_retry_after_until_a_slot_frees() {
    let dir = zoo_dir("shed");
    let policy = small_policy();
    let sock = dir.join("d.sock");
    let state = dir.join("mpqd");
    let mut dc = cfg(&dir, &sock, &state);
    dc.max_jobs = 1;
    dc.hold = true; // park the resident job so the cap stays occupied
    let h = spawn_daemon(dc);

    let mut c = dial_client(&sock);
    let id1 = c.submit("srv_a", &policy).unwrap();

    let mut c2 = dial_client(&sock);
    c2.set_retries(1);
    let err = c2.submit("srv_b", &policy).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("shed") && msg.contains("admission refused"),
        "shed error must be typed and carry the cause: {msg}"
    );
    assert!(
        wire_stat(&c.status().unwrap(), "sheds") >= 2,
        "every RETRY_AFTER must be counted"
    );

    // a freed slot turns the same retried submit into an admission
    c.cancel(id1).unwrap();
    let id2 = c2.submit("srv_b", &policy).unwrap();
    c.release().unwrap();
    let base_b = run_local(&dir, "srv_b", &policy, 0, None).unwrap().to_string();
    let res = dial_client(&sock).watch(id2, |_| {}).unwrap();
    assert_eq!(result_text(&res), base_b, "post-shed job result differs from serial");

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
    assert_no_strands(&state, "shed");
}

/// Per-job deadlines cancel gracefully: the job fails at a phase boundary
/// with a typed error, the journal survives, and an idem-keyed resubmit
/// with a workable deadline revives the **same** job — kept barriers
/// replay, only the rest is recomputed, result byte-equal to serial.
#[test]
fn deadline_cancel_keeps_the_journal_and_an_idem_resubmit_resumes_it() {
    const KEY: &str = "chaos-idem-deadline";
    let dir = zoo_dir("deadline");
    let policy = small_policy();
    let base = run_local(&dir, "srv_a", &policy, 0, None).unwrap().to_string();

    let sock = dir.join("d.sock");
    let state = dir.join("mpqd");
    let h = spawn_daemon(cfg(&dir, &sock, &state));
    let mut c = dial_client(&sock);

    let doomed = JobPolicy { deadline_ms: Some(1), ..policy.clone() };
    let id = c.submit_idem("srv_a", &doomed, KEY).unwrap();
    let err = dial_client(&sock).watch(id, |_| {}).expect_err("1ms deadline must cancel");
    let msg = format!("{err:#}");
    assert!(msg.contains("deadline exceeded"), "cancel must be typed: {msg}");
    assert!(
        state.join(format!("job_{id}.mpqj")).exists(),
        "graceful cancel must keep the journal"
    );
    assert!(wire_stat(&c.status().unwrap(), "deadline_cancels") >= 1);

    let relaxed = JobPolicy { deadline_ms: None, ..policy.clone() };
    let again = c.submit_idem("srv_a", &relaxed, KEY).unwrap();
    assert_eq!(again, id, "revival must reuse the job id");
    let res = dial_client(&sock).watch(id, |_| {}).unwrap();
    assert_eq!(result_text(&res), base, "revived result differs from serial");
    assert!(
        durability(&res, "replayed") > 0,
        "the kept journal must replay on revival: {res}"
    );

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
    assert_no_strands(&state, "deadline + revival");
}
