//! Hermetic end-to-end tests on the pure-Rust sim backend.
//!
//! These are the tier-1 counterpart of `integration.rs`: the *same*
//! Phase-1 sweep, all four Phase-2 searches, the evaluation pool and the
//! on-disk caches, exercised end-to-end on a generated `sim` model zoo —
//! no PJRT artifacts, no `xla` shared library, **zero skips** (see
//! `rust/tests/README.md` for the two test tiers).  The one exception is
//! the PJRT↔sim parity smoke test at the bottom, which is artifacts-gated
//! by design.
//!
//! Each test generates its own artifacts directory (generation is
//! milliseconds), so tests stay parallel-safe and deterministic: the same
//! `SimSpec` always produces byte-identical weights, data and manifest.

use mpq::adaround::AdaRoundCfg;
use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::engine::Evaluator;
use mpq::groups::{Assignment, Candidate, Lattice};
use mpq::manifest::Manifest;
use mpq::model::{QuantConfig, WeightOverrides};
use mpq::pool::{EvalFleet, FaultPlan, ProbeKind, CALIB_SET};
use mpq::sensitivity::{Metric, SensEntry};
use mpq::sim::{self, SimSpec};
use mpq::tensor::Tensor;
use std::collections::HashMap;

const MODEL: &str = "sim_mlp";

/// Fresh sim artifacts under a per-test temp dir.
fn sim_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_sim_e2e_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    sim::generate(&dir, &SimSpec::default()).expect("generate sim artifacts");
    dir
}

fn pipe(dir: &std::path::Path) -> Pipeline {
    let mut p = Pipeline::open(dir, MODEL).expect("open sim_mlp");
    p.calibrate(128, 0).expect("calibrate");
    p
}

#[test]
fn sim_manifest_loads_and_groups_partition() {
    let dir = sim_dir("manifest");
    let man = Manifest::load(&dir).unwrap();
    assert_eq!(man.backend, "sim");
    assert!(!man.models.is_empty());
    for m in &man.models {
        Assignment::validate_partition(m).unwrap_or_else(|e| panic!("{}: {e}", m.name));
        assert!(m.total_macs > 0);
        assert_eq!(
            m.total_macs,
            m.groups.iter().map(|g| g.macs).sum::<u64>(),
            "group MACs don't sum to total"
        );
        for l in &m.layers {
            let gw = m
                .groups
                .iter()
                .position(|g| g.w_q.contains(&l.w_q))
                .expect("layer w_q in some group");
            for a in &l.in_acts {
                assert!(m.groups[gw].act_q.contains(a), "{}: act {a} not grouped", l.name);
            }
        }
    }
}

#[test]
fn sim_fp32_matches_recorded_metric() {
    let dir = sim_dir("fp32");
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    let fp = p.eval_fp32().unwrap();
    let want = p.model.entry.fp32_val_metric;
    assert!(
        (fp - want).abs() < 1e-12,
        "rust fp32 {fp} != generated {want} — interpreter drift"
    );
}

#[test]
fn sim_lower_bits_lower_sqnr() {
    let dir = sim_dir("monotone");
    let p = pipe(&dir);
    let set = p.calib_set().unwrap();
    let ev = Evaluator::new(&p.model, set);
    let at = |bits: u8| {
        let cfg = QuantConfig {
            act: vec![Some(bits); p.model.entry.n_act()],
            w: vec![None; p.model.entry.n_w()],
        };
        ev.sqnr(&cfg, &HashMap::new()).unwrap()
    };
    let (s4, s8, s16) = (at(4), at(8), at(16));
    assert!(s4 < s8 && s8 < s16, "SQNR not monotone: {s4} {s8} {s16}");
    assert!(s16 > 40.0, "A16 SQNR only {s16} dB — activation path broken");
}

/// Phase 1 end-to-end: complete sorted list at `1 + probes`
/// forward-sweep-equivalents, reference served from cache on re-sweep.
#[test]
fn sim_phase1_sweep_end_to_end() {
    let dir = sim_dir("phase1");
    let p = pipe(&dir);
    let nb = p.calib_set().unwrap().batches.len() as u64;
    let lat = Lattice::practical();
    assert_eq!(*p.model.fwd_calls.borrow(), 0, "calibration must not run forward");
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flippable = (0..p.model.entry.groups.len())
        .filter(|&g| Assignment::flippable(&p.model.entry, g))
        .count();
    assert_eq!(sens.len(), flippable * (lat.candidates.len() - 1));
    for w in sens.windows(2) {
        assert!(w[0].score >= w[1].score, "list not sorted");
    }
    assert!(sens.iter().all(|e| e.score.is_finite()), "degenerate probe score");
    let fwd1 = *p.model.fwd_calls.borrow();
    assert_eq!(fwd1, (1 + sens.len() as u64) * nb, "sweep not 1 + probes sweeps");
    let sens2 = p.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(*p.model.fwd_calls.borrow() - fwd1, sens2.len() as u64 * nb);
    assert!(p.model.engine.ref_hits.get() > 0);
}

/// Phase 2 end-to-end: all four searches with their pinned eval counts.
#[test]
fn sim_phase2_all_four_searches() {
    let dir = sim_dir("phase2");
    let mut p = pipe(&dir);
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    let flips = p.flips(&lat, &sens);
    assert!(!flips.is_empty(), "no flips — degenerate sim zoo");
    let nb_val = p.val_set().unwrap().batches.len() as u64;
    let min_r = mpq::bops::min_rel_bops(&p.model.entry, &lat);

    // 1. BOPs budget: pure ledger walk + exactly one metric evaluation
    let fwd0 = *p.model.fwd_calls.borrow();
    for budget in [0.75, 0.5, 0.375] {
        let run = p.search_bops_budget(&lat, &flips, budget).unwrap();
        assert!(
            run.final_rel_bops <= budget + 1e-9 || (run.final_rel_bops - min_r).abs() < 1e-9,
            "budget {budget} not met: r={}",
            run.final_rel_bops
        );
        assert_eq!(run.evals, 1, "bops_budget needs exactly one final eval");
    }
    assert_eq!(*p.model.fwd_calls.borrow() - fwd0, 3 * nb_val);

    // 2. full pareto curve: flips + 1 distinct evals, memoized finish
    let fwd1 = *p.model.fwd_calls.borrow();
    let curve = p.pareto_curve_val(&lat, &flips, None).unwrap();
    assert_eq!(curve.evals, flips.len() + 1, "full_curve must not re-eval in finish");
    assert_eq!(curve.memo_hits, 1);
    assert_eq!(
        *p.model.fwd_calls.borrow() - fwd1,
        (flips.len() as u64 + 1) * nb_val
    );
    assert_eq!(curve.curve.len(), flips.len() + 1);

    // 3/4/5. accuracy targets: a target inside the curve's metric range so
    // every scheme has a real boundary to find
    let fp = p.eval_fp32().unwrap();
    let m_lo = curve.curve.iter().map(|&(_, m)| m).fold(f64::INFINITY, f64::min);
    let target = (fp + m_lo) / 2.0;
    let seq = p
        .search_accuracy_target(&lat, &flips, target, SearchScheme::Sequential, None)
        .unwrap();
    let bin = p
        .search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
        .unwrap();
    let hyb = p
        .search_accuracy_target(&lat, &flips, target, SearchScheme::Hybrid, None)
        .unwrap();
    for (name, run) in [("seq", &seq), ("bin", &bin), ("hyb", &hyb)] {
        assert!(
            run.final_metric >= target - 1e-9,
            "{name} violates target: {} < {target}",
            run.final_metric
        );
    }
    let bound = ((flips.len() + 1) as f64).log2().ceil() as usize + 1;
    assert!(bin.evals <= bound, "binary used {} evals, bound {bound}", bin.evals);
}

/// PR 2's exactness guarantee, finally exercised end-to-end: pooled
/// Phase-1 lists and Phase-2 runs are **bit-identical** to the serial path
/// at every worker count — byte-equal scores, identical flip sequences,
/// byte-equal curves and final metrics.
#[test]
fn sim_pool_matches_serial_bit_for_bit() {
    let dir = sim_dir("pool_bits");
    let lat = Lattice::practical();

    // serial reference
    let mut sp = pipe(&dir);
    let ssens = sp.sensitivity_sqnr(&lat).unwrap();
    let sflips = sp.flips(&lat, &ssens);
    let sfp = sp.eval_fp32().unwrap();
    let scurve = sp.pareto_curve_val(&lat, &sflips, None).unwrap();
    let target = (sfp + scurve.curve.iter().map(|&(_, m)| m).fold(f64::INFINITY, f64::min)) / 2.0;
    let srun = sp
        .search_accuracy_target(&lat, &sflips, target, SearchScheme::Binary, None)
        .unwrap();

    for workers in [1usize, 2, 4] {
        let mut p = Pipeline::open(&dir, MODEL).unwrap();
        p.enable_pool(workers).unwrap();
        p.calibrate(128, 0).unwrap();
        let sens = p.sensitivity_sqnr(&lat).unwrap();
        assert_eq!(sens.len(), ssens.len(), "w={workers}");
        for (a, b) in sens.iter().zip(&ssens) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "w={workers}: order diverged");
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "w={workers}: score for (g{}, {:?}): {} vs {}",
                a.group,
                a.cand,
                a.score,
                b.score
            );
        }
        let flips = p.flips(&lat, &sens);
        assert_eq!(flips.len(), sflips.len(), "w={workers}");
        for (a, b) in flips.iter().zip(&sflips) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "w={workers}: flip sequence");
        }
        let fp = p.eval_fp32().unwrap();
        assert_eq!(fp.to_bits(), sfp.to_bits(), "w={workers}: fp32 metric differs");

        // full curve through SearchCtx::with_pool (via the pipeline)
        let curve = p.pareto_curve_val(&lat, &flips, None).unwrap();
        assert_eq!(curve.curve.len(), scurve.curve.len(), "w={workers}");
        for ((r1, m1), (r2, m2)) in curve.curve.iter().zip(&scurve.curve) {
            assert_eq!(r1.to_bits(), r2.to_bits(), "w={workers}: curve r differs");
            assert_eq!(m1.to_bits(), m2.to_bits(), "w={workers}: curve metric differs");
        }

        let run = p
            .search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
            .unwrap();
        assert_eq!(run.applied.len(), srun.applied.len(), "w={workers}: chosen prefix");
        for (a, b) in run.applied.iter().zip(&srun.applied) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "w={workers}: applied flips");
        }
        assert_eq!(run.final_rel_bops.to_bits(), srun.final_rel_bops.to_bits(), "w={workers}");
        assert_eq!(run.final_metric.to_bits(), srun.final_metric.to_bits(), "w={workers}");
    }
}

/// The pool memo must be keyed by override *content*: two probes of the
/// same bit configuration that differ only in one layer's override tensor
/// must compute independently and never collide — and a re-submit of a
/// finished probe must be a pure memo hit with the identical value.
#[test]
fn sim_pool_probe_memo_never_serves_stale_overrides() {
    let dir = sim_dir("pool_memo");
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.enable_pool(2).unwrap();
    p.calibrate(64, 0).unwrap();

    let entry = p.model.entry.clone();
    let cfg = QuantConfig::fixed(&entry, 8, 8);
    let pidx = entry.w_quantizers[0].param_idx;
    let zeros = Tensor::zeros(&entry.params[pidx].shape);
    let halved = {
        let w = &p.model.weights[pidx];
        let v: Vec<f32> = w.f32s().unwrap().iter().map(|x| x * 0.5).collect();
        Tensor::from_f32(&w.shape, v).unwrap()
    };
    let mut ov_a = WeightOverrides::new();
    ov_a.insert(pidx, zeros);
    let mut ov_b = WeightOverrides::new();
    ov_b.insert(pidx, halved);

    let pool = p.pool.as_ref().unwrap();
    let (c0, h0) = (pool.probes_computed(), pool.memo_hits());
    let va = pool.submit(CALIB_SET, ProbeKind::Sqnr, &cfg, &ov_a).unwrap().wait().unwrap();
    let vb = pool.submit(CALIB_SET, ProbeKind::Sqnr, &cfg, &ov_b).unwrap().wait().unwrap();
    let vp = pool
        .submit(CALIB_SET, ProbeKind::Sqnr, &cfg, &WeightOverrides::new())
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(pool.probes_computed() - c0, 3, "three distinct probes must compute");
    assert_eq!(pool.memo_hits(), h0, "no hits expected yet");
    assert_ne!(va.to_bits(), vb.to_bits(), "override digests collided");
    assert_ne!(va.to_bits(), vp.to_bits(), "override and plain probes collided");

    let va2 = pool.submit(CALIB_SET, ProbeKind::Sqnr, &cfg, &ov_a).unwrap().wait().unwrap();
    assert_eq!(pool.probes_computed() - c0, 3, "re-submit must not recompute");
    assert_eq!(pool.memo_hits() - h0, 1, "re-submit must be a memo hit");
    assert_eq!(va2.to_bits(), va.to_bits(), "memo returned a different value");
}

/// Pooled FIT sensitivity (shard-parallel grad²/err² accumulation with
/// the serial fold replayed over raw per-batch outputs) must be
/// **bit-identical** to the serial FIT path at every worker count.
#[test]
fn sim_pooled_fit_matches_serial_bit_for_bit() {
    let dir = sim_dir("pool_fit");
    let lat = Lattice::practical();
    let sp = pipe(&dir);
    let serial = sp.sensitivity(&lat, Metric::Fit, None).unwrap();
    assert!(!serial.is_empty());
    assert!(serial.iter().all(|e| e.score.is_finite()), "degenerate FIT scores");
    for workers in [1usize, 2, 4] {
        let mut p = Pipeline::open(&dir, MODEL).unwrap();
        p.enable_pool(workers).unwrap();
        p.calibrate(128, 0).unwrap();
        let pooled = p.sensitivity(&lat, Metric::Fit, None).unwrap();
        assert_eq!(pooled.len(), serial.len(), "w={workers}");
        for (a, b) in pooled.iter().zip(&serial) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "w={workers}: order diverged");
            assert_eq!(
                a.score.to_bits(),
                b.score.to_bits(),
                "w={workers}: FIT score for (g{}, {:?}): {} vs {}",
                a.group,
                a.cand,
                a.score,
                b.score
            );
        }
    }
}

/// Pooled AdaRound (independent `(layer, wbits)` optimizations dispatched
/// to fleet workers round-robin) must produce **byte-equal rounded weight
/// tensors** vs the serial loop, at every worker count — and the stitched
/// Phase-1 sweep over them must agree bit-for-bit too.
#[test]
fn sim_pooled_adaround_matches_serial_bit_for_bit() {
    let dir = sim_dir("pool_ar");
    let lat = Lattice::practical();
    let cfg = AdaRoundCfg { steps: 30, ..Default::default() };
    let sp = pipe(&dir);
    let serial = sp.adaround(&lat, &cfg).unwrap();
    assert!(!serial.is_empty(), "no adaround layers in the sim zoo");
    let s_sens = sp.sensitivity(&lat, Metric::Sqnr, Some(&serial)).unwrap();
    for workers in [1usize, 2, 4] {
        let mut p = Pipeline::open(&dir, MODEL).unwrap();
        p.enable_pool(workers).unwrap();
        p.calibrate(128, 0).unwrap();
        let pooled = p.adaround(&lat, &cfg).unwrap();
        assert_eq!(pooled.len(), serial.len(), "w={workers}");
        for (key, st) in &serial {
            let pt = pooled
                .get(key)
                .unwrap_or_else(|| panic!("w={workers}: missing rounded {key:?}"));
            assert_eq!(pt.shape, st.shape, "w={workers}: {key:?} shape");
            let (pv, sv) = (pt.f32s().unwrap(), st.f32s().unwrap());
            for (i, (a, b)) in pv.iter().zip(sv).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "w={workers}: rounded {key:?}[{i}]: {a} vs {b}"
                );
            }
        }
        let p_sens = p.sensitivity(&lat, Metric::Sqnr, Some(&pooled)).unwrap();
        assert_eq!(p_sens.len(), s_sens.len(), "w={workers}");
        for (a, b) in p_sens.iter().zip(&s_sens) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "w={workers}");
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "w={workers}: stitched sweep");
        }
    }
}

/// One fleet, two models: attaching and probing the second model must not
/// recompile (or even re-open) the first model's executables, and the
/// first model's results stay bit-identical before/after.
#[test]
fn sim_fleet_shares_workers_across_models_without_recompiling() {
    let dir = std::env::temp_dir().join("mpq_sim_e2e_fleet2");
    std::fs::remove_dir_all(&dir).ok();
    let spec_a = SimSpec::default();
    let spec_b = SimSpec {
        name: "sim_mlp_b".into(),
        dims: vec![12, 18, 10],
        seed: 23,
        ..Default::default()
    };
    sim::generate_zoo(&dir, &[spec_a.clone(), spec_b.clone()]).unwrap();
    let workers = 2usize;
    let fleet = EvalFleet::new(&dir, workers).unwrap();
    let lat = Lattice::practical();

    let mut pa = Pipeline::open(&dir, &spec_a.name).unwrap();
    pa.attach_fleet(&fleet).unwrap();
    pa.calibrate(64, 0).unwrap();
    let sa1 = pa.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(fleet.model_opens(), workers, "model A opened once per worker");
    let stats_a = fleet.worker_stats().unwrap();
    assert!(stats_a.iter().all(|s| s.models_open == 1));

    // attach + probe the second model on the SAME fleet
    let mut pb = Pipeline::open(&dir, &spec_b.name).unwrap();
    pb.attach_fleet(&fleet).unwrap();
    pb.calibrate(64, 0).unwrap();
    let sb = pb.sensitivity_sqnr(&lat).unwrap();
    assert!(!sb.is_empty());
    assert_eq!(fleet.model_opens(), 2 * workers, "model B opened once per worker");
    let stats_ab = fleet.worker_stats().unwrap();
    for (i, (a, b)) in stats_a.iter().zip(&stats_ab).enumerate() {
        assert_eq!(
            b.compiled,
            a.compiled + 1,
            "worker {i}: attaching B must compile only B's forward"
        );
        assert_eq!(b.models_open, 2);
    }

    // re-sweep A on the shared fleet: ZERO recompilations, identical bits
    pa.clear_eval_memo();
    let sa2 = pa.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(fleet.model_opens(), 2 * workers, "re-probing A must not re-open");
    let stats_after = fleet.worker_stats().unwrap();
    for (i, (x, y)) in stats_ab.iter().zip(&stats_after).enumerate() {
        assert_eq!(x.compiled, y.compiled, "worker {i}: re-probing A recompiled something");
    }
    assert_eq!(sa1.len(), sa2.len());
    for (a, b) in sa1.iter().zip(&sa2) {
        assert_eq!((a.group, a.cand), (b.group, b.cand));
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "A diverged after B attached");
    }

    // dropping B's last client evicts its worker slots; A keeps serving
    drop(pb);
    let stats_drop = fleet.worker_stats().unwrap();
    assert!(stats_drop.iter().all(|s| s.models_open == 1), "detach must evict B");
    pa.clear_eval_memo();
    let sa3 = pa.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(sa3[0].score.to_bits(), sa1[0].score.to_bits());
}

/// Resizing the fleet mid-run re-shards the registered sets and keeps
/// every later sweep bit-identical to the serial reference.
#[test]
fn sim_fleet_resize_mid_run() {
    let dir = sim_dir("resize");
    let lat = Lattice::practical();
    let serial = pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    let fleet = EvalFleet::new(&dir, 1).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let check = |p: &Pipeline, tag: &str| {
        p.clear_eval_memo();
        let sens = p.sensitivity_sqnr(&lat).unwrap();
        assert_eq!(sens.len(), serial.len(), "{tag}");
        for (a, b) in sens.iter().zip(&serial) {
            assert_eq!((a.group, a.cand), (b.group, b.cand), "{tag}");
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "{tag}: score diverged");
        }
    };
    check(&p, "w=1 before resize");
    fleet.resize(3).unwrap();
    assert_eq!(fleet.workers(), 3);
    check(&p, "after grow to 3");
    fleet.resize(2).unwrap();
    assert_eq!(fleet.workers(), 2);
    check(&p, "after shrink to 2");
    // Phase 2 still works across a resize (val set re-sharded too)
    let flips = p.flips(&lat, &serial);
    let run = p.search_bops_budget(&lat, &flips, 0.5).unwrap();
    assert!(run.final_metric.is_finite());
}

/// On-disk FP32 reference cache: a pooled run persists the merged
/// reference; a later serial run restores it with ZERO reference forward
/// sweeps and produces bit-identical Phase-1 scores.
#[test]
fn sim_reference_cache_skips_reference_sweep() {
    let dir = sim_dir("refcache");
    let cache = dir.join("sens_cache");
    let lat = Lattice::practical();

    // pooled first run: reference-cache miss → build (shard-parallel),
    // fetch back, persist
    let mut pp = Pipeline::open(&dir, MODEL).unwrap();
    pp.enable_pool(2).unwrap();
    pp.set_sens_cache_dir(Some(cache.clone()));
    pp.calibrate(128, 0).unwrap();
    assert_eq!(pp.ref_cache_stats(), (0, 1), "first calibrate is a ref miss");
    let sp = pp.sensitivity_sqnr(&lat).unwrap();
    let ref_files: Vec<_> = std::fs::read_dir(&cache)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.file_name().to_string_lossy().starts_with("ref_"))
        .collect();
    assert_eq!(ref_files.len(), 1, "pooled run must persist the reference");

    // wipe the sensitivity lists (keep the reference) so the second run
    // actually sweeps
    for e in std::fs::read_dir(&cache).unwrap().filter_map(|e| e.ok()) {
        if e.file_name().to_string_lossy().starts_with("sens_") {
            std::fs::remove_file(e.path()).unwrap();
        }
    }

    // serial second run: reference restored from disk — no reference
    // sweep, probe-only forward accounting, bit-identical scores
    let mut ps = Pipeline::open(&dir, MODEL).unwrap();
    ps.set_sens_cache_dir(Some(cache));
    ps.calibrate(128, 0).unwrap();
    assert_eq!(ps.ref_cache_stats(), (1, 0), "second calibrate must hit");
    let fwd0 = *ps.model.fwd_calls.borrow();
    let ss = ps.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(ps.model.engine.ref_builds.get(), 0, "reference must come from disk");
    let nb = ps.calib_set().unwrap().batches.len() as u64;
    assert_eq!(
        *ps.model.fwd_calls.borrow() - fwd0,
        ss.len() as u64 * nb,
        "sweep must cost probes only — no reference sweep"
    );
    assert_eq!(ss.len(), sp.len());
    for (a, b) in ss.iter().zip(&sp) {
        assert_eq!((a.group, a.cand), (b.group, b.cand));
        assert_eq!(
            a.score.to_bits(),
            b.score.to_bits(),
            "disk-restored reference diverged from pooled-built one"
        );
    }
}

#[test]
fn sim_ood_calibration_runs() {
    let dir = sim_dir("ood");
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    let x = p.model.data.ood_calib.clone().expect("generated ood pool");
    let sub = x.slice_rows(0, 64).unwrap();
    p.calibrate_unlabeled(&sub).unwrap();
    let lat = Lattice::practical_no16();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert!(!sens.is_empty());
    assert!(sens.iter().all(|e| e.score.is_finite()));
}

/// On-disk sensitivity cache, hermetically: second sweep served from disk,
/// bit-identically, with zero forward calls.
#[test]
fn sim_sens_cache_skips_repeat_sweeps() {
    let dir = sim_dir("senscache");
    let cache = dir.join("sens_cache");
    let lat = Lattice::practical();
    let mut p = pipe(&dir);
    p.set_sens_cache_dir(Some(cache));
    let first = p.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(p.sens_cache_stats(), (0, 1), "first sweep is a miss");
    let fwd = *p.model.fwd_calls.borrow();
    let second = p.sensitivity_sqnr(&lat).unwrap();
    assert_eq!(p.sens_cache_stats(), (1, 1), "second sweep must hit");
    assert_eq!(*p.model.fwd_calls.borrow(), fwd, "cache hit must cost zero forwards");
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        assert_eq!((a.group, a.cand), (b.group, b.cand));
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "scores must round-trip");
    }
}

/// EvalSet ragged-tail truncation contract on the sim backend.
#[test]
fn sim_eval_set_truncates_ragged_subset_consistently() {
    let dir = sim_dir("ragged");
    let p = Pipeline::open(&dir, MODEL).unwrap();
    let batch = p.model.entry.batch;
    let ragged = batch + batch / 2 + 1;
    let ds = p.model.data.val.take(ragged).unwrap();
    let set = p.model.eval_set(&ds).unwrap();
    assert_eq!(set.batches.len(), ragged / batch);
    assert_eq!(set.n, (ragged / batch) * batch);
    assert_eq!(set.labels.shape[0], set.n);
}

/// Weight overrides flow through the sim forward exactly like PJRT:
/// overriding a parameter changes the logits and disables its quantizer.
#[test]
fn sim_weight_override_changes_logits() {
    let dir = sim_dir("override");
    let p = pipe(&dir);
    let set = p.calib_set().unwrap();
    let cfg = QuantConfig::fp32(&p.model.entry);
    let cb = p.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    let base = p.model.logits_on(set, &cb).unwrap();
    let pidx = p.model.entry.w_quantizers[0].param_idx;
    let zero = Tensor::zeros(&p.model.entry.params[pidx].shape);
    let mut ov = HashMap::new();
    ov.insert(pidx, zero);
    let cb2 = p.model.config_buffers(&cfg, &ov).unwrap();
    let changed = p.model.logits_on(set, &cb2).unwrap();
    assert_ne!(base.f32s().unwrap(), changed.f32s().unwrap());
}

/// Mixed precision beats or matches the fixed config at the same BOPs on
/// the sim zoo — the paper's core claim, now asserted on every CI run.
#[test]
fn sim_mixed_beats_or_matches_fixed_at_same_bops() {
    let dir = sim_dir("mp_vs_fixed");
    let mut p = pipe(&dir);
    let lat = Lattice::practical();
    let w8a8 = p.eval_fixed(Candidate::new(8, 8), None).unwrap();
    let run = p.mixed_precision_for_budget(&lat, 0.5).unwrap();
    assert!(run.final_rel_bops <= 0.5 + 1e-9);
    assert!(
        run.final_metric >= w8a8 - 0.08,
        "MP {} much worse than fixed W8A8 {}",
        run.final_metric,
        w8a8
    );
}

// ---------------------------------------------------------------------------
// Self-healing fleet: deterministic fault injection, supervised recovery.
// The plans are explicit (`with_faults`), so these stay deterministic even
// under the fault-injection CI job's MPQ_FAULT_PLAN.
// ---------------------------------------------------------------------------

/// Two Phase-1 lists agree in order and **bit-for-bit** scores.
fn assert_sens_bits(got: &[SensEntry], want: &[SensEntry], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: list length");
    for (a, b) in got.iter().zip(want) {
        assert_eq!((a.group, a.cand), (b.group, b.cand), "{tag}: order diverged");
        assert_eq!(
            a.score.to_bits(),
            b.score.to_bits(),
            "{tag}: score for (g{}, {:?}): {} vs {}",
            a.group,
            a.cand,
            a.score,
            b.score
        );
    }
}

/// ISSUE-6 acceptance #1: a worker panics while serving its 3rd probe, mid
/// Phase-1 sweep at w=4.  The supervisor respawns the lane, replays its
/// state and requeues everything it owed — the sweep completes with
/// exactly one restart and scores/curves **byte-equal** to the serial
/// oracle (and hence to the fault-free w=4 run, which
/// `sim_pool_matches_serial_bit_for_bit` pins to the same bits).
#[test]
fn sim_fleet_survives_worker_panic_mid_sweep() {
    let dir = sim_dir("heal_panic");
    let lat = Lattice::practical();

    let mut sp = pipe(&dir);
    let ssens = sp.sensitivity_sqnr(&lat).unwrap();
    let sflips = sp.flips(&lat, &ssens);
    let scurve = sp.pareto_curve_val(&lat, &sflips, None).unwrap();

    let plan = FaultPlan::parse("panic@1:3,backoff:0").unwrap();
    let fleet = EvalFleet::with_faults(&dir, 4, plan).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &ssens, "panic@1:3 w=4");

    let fs = fleet.failure_stats();
    assert_eq!(fs.faults_injected, 1, "the panic must fire exactly once");
    assert_eq!(fs.worker_restarts, 1, "one respawn heals the fleet");
    assert!(fs.jobs_requeued > 0, "the dead worker's slots must be requeued");
    assert!(fs.degraded_events.is_empty(), "death within budget must not degrade");
    assert_eq!(fleet.workers(), 4, "fleet back at full strength");
    assert!(
        fs.last_deaths.iter().any(|d| d.contains("injected fault")),
        "death reason must carry the injected root cause: {:?}",
        fs.last_deaths
    );

    // Phase 2 on the healed fleet: byte-equal pareto curve
    let flips = p.flips(&lat, &sens);
    let curve = p.pareto_curve_val(&lat, &flips, None).unwrap();
    assert_eq!(curve.curve.len(), scurve.curve.len());
    for ((r1, m1), (r2, m2)) in curve.curve.iter().zip(&scurve.curve) {
        assert_eq!(r1.to_bits(), r2.to_bits(), "curve r diverged after healing");
        assert_eq!(m1.to_bits(), m2.to_bits(), "curve metric diverged after healing");
    }
}

/// ISSUE-6 acceptance #2: a *recurring* panic exhausts the lane's restart
/// budget — the fleet degrades gracefully to the survivors (reaping the
/// lane, re-sharding state, re-dispatching orphans) and the run completes
/// with the same bits; later sweeps on the shrunken fleet stay exact too.
#[test]
fn sim_fleet_degrades_after_restart_budget() {
    let dir = sim_dir("heal_degrade");
    let lat = Lattice::practical();
    let serial = pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    // lane 1 panics on the FIRST probe of every incarnation; budget 2 →
    // two respawns burn, the third death retires the lane
    let plan = FaultPlan::parse("panic@1:1*,budget:2,backoff:0").unwrap();
    let fleet = EvalFleet::with_faults(&dir, 3, plan).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &serial, "degraded sweep");

    let fs = fleet.failure_stats();
    assert_eq!(fs.worker_restarts, 2, "budget 2 allows exactly two respawns");
    assert_eq!(fs.faults_injected, 3, "one panic per incarnation");
    assert_eq!(fs.degraded_events.len(), 1, "one lane retired: {:?}", fs.degraded_events);
    assert!(fs.jobs_requeued > 0);
    assert_eq!(fleet.workers(), 2, "dead lane must be reaped from the live count");
    assert!(
        fs.degraded_events[0].contains("restart budget"),
        "event must say why: {}",
        fs.degraded_events[0]
    );

    // the survivors keep serving fresh (non-memoized) sweeps exactly
    p.clear_eval_memo();
    let again = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&again, &serial, "post-degradation re-sweep");
    assert_eq!(
        fleet.failure_stats().faults_injected,
        3,
        "retired lane must not fire again"
    );
}

/// Deadline watchdog: a stuck (stalled, not dead) worker is converted into
/// a death after `deadline:MS` of reply silence — respawned, requeued, and
/// the sweep still finishes bit-identical to serial.  The marooned thread
/// is detached; its eventual replies carry a retired incarnation id and
/// are dropped.
#[test]
fn sim_fleet_watchdog_converts_stuck_worker_into_death() {
    let dir = sim_dir("heal_watchdog");
    let lat = Lattice::practical();
    let serial = pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    let plan = FaultPlan::parse("stall@0:2,deadline:400,backoff:0").unwrap();
    let fleet = EvalFleet::with_faults(&dir, 2, plan).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &serial, "watchdog sweep");

    let fs = fleet.failure_stats();
    assert_eq!(fs.faults_injected, 1, "the stall must fire exactly once");
    // ≥, not ==: in a pathological scheduling pause the watchdog may also
    // presume a healthy worker stuck — recovery keeps the bits identical
    // either way, which is what the sweep assertion above pins
    assert!(fs.worker_restarts >= 1, "the stuck lane must be respawned");
    assert!(fs.jobs_requeued > 0, "the stalled probe must be requeued");
    assert!(fs.degraded_events.is_empty());
    assert_eq!(fleet.workers(), 2);
    assert!(
        fs.last_deaths.iter().any(|d| d.contains("watchdog")),
        "death reason must name the watchdog: {:?}",
        fs.last_deaths
    );
}

/// An injected upload failure poisons one worker's calibration shard; the
/// first probe that touches it surfaces the **root cause** (not a bare
/// "set not loaded"), and re-pushing the set recovers the fleet to
/// bit-identical results — PR-5's fire-and-forget upload semantics under
/// faults.
#[test]
fn sim_fleet_surfaces_injected_upload_root_cause() {
    let dir = sim_dir("heal_upload");
    let lat = Lattice::practical();
    let serial = pipe(&dir).sensitivity_sqnr(&lat).unwrap();

    // lane 0's first upload is its CALIB_SET shard (val loads lazily)
    let plan = FaultPlan::parse("upload@0:1,backoff:0").unwrap();
    let fleet = EvalFleet::with_faults(&dir, 2, plan).unwrap();
    let mut p = Pipeline::open(&dir, MODEL).unwrap();
    p.attach_fleet(&fleet).unwrap();
    p.calibrate(128, 0).unwrap();
    let err = p.sensitivity_sqnr(&lat).expect_err("poisoned shard must fail the sweep");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("injected fault") && msg.contains("upload failure"),
        "sweep error must surface the injected root cause, got: {msg}"
    );
    let fs = fleet.failure_stats();
    assert_eq!(fs.faults_injected, 1);
    assert_eq!(fs.worker_restarts, 0, "an upload failure is not a death");

    // recovery: re-pushing calibration re-uploads the set (fault depleted)
    p.calibrate(128, 0).unwrap();
    let sens = p.sensitivity_sqnr(&lat).unwrap();
    assert_sens_bits(&sens, &serial, "post-recovery sweep");
}

/// PJRT ↔ sim parity smoke test (artifacts-gated): the HLO-lowered
/// `mlp_parity_s` and its sim re-export share weights and data, so the two
/// backends must agree on the FP32 metric and on fixed-config SQNR to
/// tolerance (not bit-exactly: jax rounds half-to-even, `quant::fq` rounds
/// half-away, and matmul accumulation orders differ).  Guards the sim
/// interpreter against semantic drift from the real lowering.
#[test]
fn pjrt_sim_parity_smoke() {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the pjrt feature");
        return;
    }
    let dir = mpq::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {}", dir.display());
        return;
    }
    if Manifest::load(&dir).map(|m| m.model("mlp_parity_s").is_err()).unwrap_or(true) {
        eprintln!("SKIP: no mlp_parity_s in artifacts — re-run `make artifacts`");
        return;
    }
    let sdir = std::env::temp_dir().join("mpq_sim_parity");
    std::fs::remove_dir_all(&sdir).ok();
    sim::export_from_artifacts(&dir, "mlp_parity_s", &sdir).expect("export sim twin");

    let mut pj = Pipeline::open(&dir, "mlp_parity_s").unwrap();
    let mut sm = Pipeline::open(&sdir, "mlp_parity_s").unwrap();
    pj.calibrate(128, 0).unwrap();
    sm.calibrate(128, 0).unwrap();

    let (fp_pj, fp_sm) = (pj.eval_fp32().unwrap(), sm.eval_fp32().unwrap());
    assert!(
        (fp_pj - fp_sm).abs() < 0.02,
        "FP32 metric drift: pjrt {fp_pj} vs sim {fp_sm}"
    );
    for (w, a) in [(8u8, 8u8), (4, 8)] {
        let sq = |p: &Pipeline| {
            let set = p.calib_set().unwrap();
            let ev = Evaluator::new(&p.model, set);
            let cfg = QuantConfig::fixed(&p.model.entry, w, a);
            ev.sqnr(&cfg, &HashMap::new()).unwrap()
        };
        let (s_pj, s_sm) = (sq(&pj), sq(&sm));
        assert!(
            (s_pj - s_sm).abs() < 0.5,
            "W{w}A{a} SQNR drift: pjrt {s_pj} dB vs sim {s_sm} dB"
        );
    }
}
