//! Crash/resume end-to-end tests on the pure-Rust sim backend (tier 1).
//!
//! The durability contract under test (see `src/store`): a pipeline killed
//! at **any** run-journal barrier (`crash@PHASE:N` aborts the coordinator
//! right after the Nth record is durable) and restarted with `--resume`
//! must reproduce the uninterrupted run **byte-for-byte** — sensitivity
//! lists, search curves, AdaRounded tensors and the rendered report — while
//! re-executing *zero* completed work units: every journaled record is
//! served back, only the remainder is computed and appended.  The matrix
//! covers the serial path and pooled fleets at 1/2/4 workers, every crash
//! ordinal in turn.
//!
//! Corruption is exercised end-to-end too: a torn journal tail or a
//! bit-flipped record degrades to the last valid prefix (the rest is
//! recomputed, results unchanged), and a corrupt header quarantines the
//! file and restarts fresh — never a panic, never a wrong result.

use mpq::adaround::AdaRoundCfg;
use mpq::coordinator::Pipeline;
use mpq::groups::Lattice;
use mpq::sim::{self, SimSpec};
use mpq::store::{RunJournal, StoreStats};
use mpq::tensor::io as tio;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::rc::Rc;

const MODEL: &str = "sim_mlp";
const CALIB_N: usize = 64;

/// Fresh sim artifacts under a per-test temp dir (generation is
/// deterministic: same spec → byte-identical weights and data).
fn sim_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_resume_e2e_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    let spec = SimSpec { calib_n: CALIB_N, val_n: 64, ood_n: 0, ..Default::default() };
    sim::generate(&dir, &spec).expect("generate sim artifacts");
    dir
}

/// Everything one pipeline run produces, in bit-exact form, plus the
/// durability counters the assertions key on.
struct RunOut {
    /// (group, wbits, abits, score bits) per Phase-1 entry
    sens: Vec<(usize, u8, u8, u64)>,
    /// (rel_bops bits, metric bits) per pareto-curve point
    curve: Vec<(u64, u64)>,
    /// sorted (param_idx, wbits) → MPQT-encoded rounded tensor
    rounded: Vec<((usize, u8), Vec<u8>)>,
    /// rendered final report (the byte-equality target for reports)
    report: String,
    /// forward batches the *coordinator* engine ran (serial work proxy)
    fwd_calls: u64,
    appended: u64,
    replayed: u64,
    skips: u64,
    truncations: u64,
    quarantined: u64,
}

/// One full mini-pipeline — calibrate, Phase-1 SQNR sweep, pareto curve on
/// the calibration set, AdaRound — against the journal at
/// `<dir>/journal.mpqj`.  `workers == 0` is the serial path.
fn run_n(
    dir: &Path,
    workers: usize,
    resume: bool,
    crash: Vec<u64>,
    calib_n: usize,
) -> anyhow::Result<RunOut> {
    let stats = Rc::new(StoreStats::default());
    let journal = RunJournal::open(dir.join("journal.mpqj"), resume, Rc::clone(&stats))?
        .with_crash_barriers(crash);
    let mut p = Pipeline::open(dir, MODEL)?;
    if workers > 0 {
        p.enable_pool(workers)?;
    }
    p.set_journal(Some(Rc::new(journal)));
    p.calibrate(calib_n, 0)?;
    let lat = Lattice::practical();
    let sens = p.sensitivity_sqnr(&lat)?;
    let flips = p.flips(&lat, &sens);
    let curve_run = p.pareto_curve(&lat, &flips, None)?;
    let ar_cfg = AdaRoundCfg { steps: 8, ..Default::default() };
    let rounded = p.adaround(&lat, &ar_cfg)?;

    let mut report = mpq::report::Table::new("resume e2e", &["k", "rel_bops", "metric"]);
    for (i, (r, m)) in curve_run.curve.iter().enumerate() {
        report.row(vec![
            i.to_string(),
            format!("{:016x}", r.to_bits()),
            format!("{:016x}", m.to_bits()),
        ]);
    }
    let mut keys: Vec<_> = rounded.keys().copied().collect();
    keys.sort_unstable();
    Ok(RunOut {
        sens: sens
            .iter()
            .map(|e| (e.group, e.cand.wbits, e.cand.abits, e.score.to_bits()))
            .collect(),
        curve: curve_run.curve.iter().map(|&(r, m)| (r.to_bits(), m.to_bits())).collect(),
        rounded: keys
            .into_iter()
            .map(|k| (k, tio::encode_tensors(std::slice::from_ref(&rounded[&k]))))
            .collect(),
        report: report.render(),
        fwd_calls: *p.model.fwd_calls.borrow(),
        appended: stats.journal_appended.get(),
        replayed: stats.journal_replayed.get(),
        skips: stats.journal_skips.get(),
        truncations: stats.journal_truncations.get(),
        quarantined: stats.files_quarantined.get(),
    })
}

fn run(dir: &Path, workers: usize, resume: bool, crash: Vec<u64>) -> anyhow::Result<RunOut> {
    run_n(dir, workers, resume, crash, CALIB_N)
}

/// Start a fresh run armed to abort at journal barrier `n` and assert it
/// actually died there (write-ahead: the Nth record is durable first).
fn run_crashing(dir: &Path, workers: usize, n: u64) {
    let res = catch_unwind(AssertUnwindSafe(|| run(dir, workers, false, vec![n])));
    let err = match res {
        Err(payload) => payload,
        Ok(r) => panic!(
            "crash@PHASE:{n} did not fire (run finished: {:?})",
            r.map(|o| o.appended)
        ),
    };
    let msg = err
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| err.downcast_ref::<&str>().copied())
        .unwrap_or("<non-string panic>");
    assert!(msg.contains("crash@PHASE"), "unexpected panic: {msg}");
}

fn assert_same_outputs(base: &RunOut, got: &RunOut, what: &str) {
    assert_eq!(base.sens, got.sens, "{what}: sensitivity lists differ");
    assert_eq!(base.curve, got.curve, "{what}: search curves differ");
    assert_eq!(base.rounded, got.rounded, "{what}: rounded tensors differ");
    assert_eq!(base.report, got.report, "{what}: rendered reports differ");
}

/// Kill at every barrier ordinal in turn, resume, and demand byte-equal
/// outputs with zero re-executed completed units.
fn crash_matrix(dir: &Path, workers: usize, base: &RunOut) {
    let total = base.appended;
    assert!(total >= 10, "w{workers}: expected a real barrier count, got {total}");
    for n in 1..=total {
        run_crashing(dir, workers, n);
        let resumed = run(dir, workers, true, vec![]).unwrap();
        assert_same_outputs(base, &resumed, &format!("w{workers} crash@{n}"));
        assert_eq!(resumed.replayed, n, "w{workers} crash@{n}: replayed records");
        assert!(
            resumed.skips >= n,
            "w{workers} crash@{n}: only {} journal skips for {n} replayed records",
            resumed.skips
        );
        assert_eq!(
            resumed.appended,
            total - n,
            "w{workers} crash@{n}: completed work was re-executed"
        );
    }
}

#[test]
fn crash_at_every_barrier_then_resume_serial() {
    let dir = sim_dir("serial");
    let base = run(&dir, 0, false, vec![]).unwrap();
    crash_matrix(&dir, 0, &base);
}

#[test]
fn crash_at_every_barrier_then_resume_w1() {
    let dir = sim_dir("w1");
    let serial = run(&dir, 0, false, vec![]).unwrap();
    let base = run(&dir, 1, false, vec![]).unwrap();
    assert_same_outputs(&serial, &base, "pooled w1 vs serial");
    assert_eq!(serial.appended, base.appended, "barrier counts diverge pooled vs serial");
    crash_matrix(&dir, 1, &base);
}

#[test]
fn crash_at_every_barrier_then_resume_w2() {
    let dir = sim_dir("w2");
    let serial = run(&dir, 0, false, vec![]).unwrap();
    let base = run(&dir, 2, false, vec![]).unwrap();
    assert_same_outputs(&serial, &base, "pooled w2 vs serial");
    crash_matrix(&dir, 2, &base);
}

#[test]
fn crash_at_every_barrier_then_resume_w4() {
    let dir = sim_dir("w4");
    let serial = run(&dir, 0, false, vec![]).unwrap();
    let base = run(&dir, 4, false, vec![]).unwrap();
    assert_same_outputs(&serial, &base, "pooled w4 vs serial");
    crash_matrix(&dir, 4, &base);
}

/// A journal holding the complete run replays everything: the resumed
/// serial run must not issue a single forward batch.
#[test]
fn completed_journal_resumes_with_zero_forward_work() {
    let dir = sim_dir("full");
    let base = run(&dir, 0, false, vec![]).unwrap();
    let resumed = run(&dir, 0, true, vec![]).unwrap();
    assert_same_outputs(&base, &resumed, "full resume");
    assert_eq!(resumed.replayed, base.appended);
    assert_eq!(resumed.appended, 0, "fully journaled resume appended new records");
    assert_eq!(resumed.fwd_calls, 0, "fully journaled resume ran forward batches");
}

/// Changing the run inputs (here: the calibration subset) moves every
/// scope digest, so a stale journal replays nothing into the changed run.
#[test]
fn stale_journal_never_replays_into_changed_run() {
    let dir = sim_dir("stale");
    let base = run(&dir, 0, false, vec![]).unwrap();
    let changed = run_n(&dir, 0, true, vec![], CALIB_N / 2).unwrap();
    assert_eq!(changed.replayed, base.appended, "stale records still replay at open");
    assert_eq!(changed.skips, 0, "stale journal records matched a changed run");
    assert!(changed.appended > 0, "changed run journaled nothing");
}

/// A write torn mid-record (process died during the final append) is
/// truncated back to the last valid record; only that one unit recomputes.
#[test]
fn torn_journal_tail_truncates_and_resumes_byte_equal() {
    let dir = sim_dir("torn");
    let base = run(&dir, 0, false, vec![]).unwrap();
    let jpath = dir.join("journal.mpqj");
    let len = std::fs::metadata(&jpath).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&jpath).unwrap();
    f.set_len(len - 5).unwrap();
    drop(f);
    let resumed = run(&dir, 0, true, vec![]).unwrap();
    assert_same_outputs(&base, &resumed, "torn tail");
    assert_eq!(resumed.truncations, 1, "torn tail not detected");
    assert_eq!(resumed.replayed, base.appended - 1, "exactly the torn record is lost");
    assert_eq!(resumed.appended, 1, "only the torn record recomputes");
}

/// A bit flip mid-file invalidates that record's checksum: replay keeps
/// the valid prefix, recomputes the rest, and the results don't change.
#[test]
fn corrupt_journal_record_degrades_to_valid_prefix() {
    let dir = sim_dir("bitflip");
    let base = run(&dir, 0, false, vec![]).unwrap();
    let jpath = dir.join("journal.mpqj");
    let mut bytes = std::fs::read(&jpath).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&jpath, &bytes).unwrap();
    let resumed = run(&dir, 0, true, vec![]).unwrap();
    assert_same_outputs(&base, &resumed, "bit flip");
    assert_eq!(resumed.truncations, 1, "corrupt frame not truncated");
    assert!(resumed.replayed < base.appended, "corrupt record still replayed");
    assert_eq!(
        resumed.appended + resumed.replayed,
        base.appended,
        "lost records must be recomputed, nothing more"
    );
}

/// A destroyed header quarantines the file (`journal.mpqj.corrupt`) and
/// restarts journaling from scratch — the run itself is unaffected.
#[test]
fn corrupt_journal_header_quarantines_and_restarts_fresh() {
    let dir = sim_dir("badheader");
    let base = run(&dir, 0, false, vec![]).unwrap();
    let jpath = dir.join("journal.mpqj");
    let mut bytes = std::fs::read(&jpath).unwrap();
    bytes[0] ^= 0xff;
    std::fs::write(&jpath, &bytes).unwrap();
    let resumed = run(&dir, 0, true, vec![]).unwrap();
    assert_same_outputs(&base, &resumed, "bad header");
    assert_eq!(resumed.replayed, 0);
    assert_eq!(resumed.quarantined, 1, "bad-header journal not quarantined");
    assert_eq!(resumed.appended, base.appended, "fresh journal must hold the full run");
    assert!(dir.join("journal.mpqj.corrupt").exists(), "quarantine file missing");
}
