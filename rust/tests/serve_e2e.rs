//! `mpqd` daemon end-to-end tests on the pure-Rust sim backend (tier 1,
//! hermetic — no PJRT, no network, one Unix socket per test).
//!
//! Contracts under test (see `src/serve`):
//!
//! * **Concurrency**: two jobs over different sim-zoo models interleave
//!   phase-by-phase on one shared fleet, stream progress events, and
//!   their final reports are byte-equal to the serial single-job path.
//!   A resubmission whose model is still warm on the fleet opens zero
//!   new model handles (zero recompiles).
//! * **Crash/resume**: a daemon killed mid-job (`crash@PHASE:N` on the
//!   job journal) restarts on the same state dir, auto-resumes the job,
//!   replays exactly the N completed units and recomputes only the rest
//!   — byte-equal result.
//! * **Admission + cancel**: submits beyond `max_jobs` are refused with
//!   a bounded error; cancel frees the slot and strands neither journal
//!   nor temp files; shutdown removes the socket.
//! * **Priority**: a high-priority job owns the schedule until done;
//!   equal-priority jobs round-robin.

use mpq::serve::daemon::{self, ServeCfg};
use mpq::serve::{run_local, Client, JobPolicy};
use mpq::sim::{self, SimSpec};
use std::path::{Path, PathBuf};
use std::thread;
use std::time::{Duration, Instant};

/// Two-model sim zoo under a per-test temp dir (generation is
/// deterministic: same specs → byte-identical artifacts).
fn zoo_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mpq_serve_e2e_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    let a = SimSpec {
        name: "srv_a".into(),
        batch: 4,
        dims: vec![8, 10, 6],
        calib_n: 32,
        val_n: 16,
        ood_n: 0,
        seed: 7,
        fault_plan: None,
    };
    let b = SimSpec { name: "srv_b".into(), dims: vec![8, 12, 6], seed: 11, ..a.clone() };
    sim::generate_zoo(&dir, &[a, b]).expect("generate sim zoo");
    dir
}

fn small_policy() -> JobPolicy {
    JobPolicy { calib_n: 16, adaround_steps: 4, ..Default::default() }
}

fn cfg(dir: &Path, sock: &Path, state: &Path) -> ServeCfg {
    ServeCfg {
        dir: dir.to_path_buf(),
        socket: sock.to_path_buf(),
        state_dir: state.to_path_buf(),
        workers: 2,
        max_idle: 2,
        max_jobs: 4,
        fault_plan: None,
        hold: false,
        io_timeout_ms: daemon::DEFAULT_IO_TIMEOUT_MS,
    }
}

fn spawn_daemon(cfg: ServeCfg) -> thread::JoinHandle<anyhow::Result<()>> {
    thread::spawn(move || daemon::run(cfg))
}

/// Connect and prove liveness with a `status` round trip — a stale
/// socket from a killed daemon accepts connections but can't answer.
fn connect(socket: &Path) -> Client {
    for _ in 0..1000 {
        if let Ok(mut c) = Client::connect(socket) {
            if c.status().is_ok() {
                return c;
            }
        }
        thread::sleep(Duration::from_millis(10));
    }
    panic!("daemon on {} never became reachable", socket.display());
}

fn result_text(payload: &mpq::jsonio::Json) -> String {
    payload.req("result").unwrap().to_string()
}

fn durability(payload: &mpq::jsonio::Json, field: &str) -> u64 {
    payload.req("durability").unwrap().req(field).unwrap().as_f64().unwrap() as u64
}

fn sched_log(status: &mpq::jsonio::Json) -> Vec<String> {
    status
        .req("sched_log")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.as_str().unwrap().to_string())
        .collect()
}

fn model_opens(status: &mpq::jsonio::Json) -> u64 {
    status
        .req("telemetry")
        .unwrap()
        .req("fleet")
        .unwrap()
        .req("model_opens")
        .unwrap()
        .as_f64()
        .unwrap() as u64
}

#[test]
fn concurrent_jobs_interleave_and_match_serial() {
    let dir = zoo_dir("conc");
    let policy = small_policy();
    let base_a = run_local(&dir, "srv_a", &policy, 0, None).unwrap().to_string();
    let base_b = run_local(&dir, "srv_b", &policy, 0, None).unwrap().to_string();

    let sock = dir.join("d.sock");
    let mut dc = cfg(&dir, &sock, &dir.join("mpqd"));
    dc.hold = true; // stage both jobs before any work starts
    let h = spawn_daemon(dc);
    let mut c = connect(&sock);
    let ida = c.submit("srv_a", &policy).unwrap();
    let idb = c.submit("srv_b", &policy).unwrap();

    let wa = connect(&sock);
    let wb = connect(&sock);
    let ta = thread::spawn(move || {
        let mut events = Vec::new();
        let res = wa.watch(ida, |e| events.push(e.to_string())).unwrap();
        (events, res)
    });
    let tb = thread::spawn(move || {
        let mut events = Vec::new();
        let res = wb.watch(idb, |e| events.push(e.to_string())).unwrap();
        (events, res)
    });
    thread::sleep(Duration::from_millis(150)); // let both subscriptions land
    c.release().unwrap();

    let (ev_a, res_a) = ta.join().unwrap();
    let (ev_b, res_b) = tb.join().unwrap();

    // final reports byte-equal to the serial single-job path
    assert_eq!(result_text(&res_a), base_a, "daemon result for srv_a differs from serial");
    assert_eq!(result_text(&res_b), base_b, "daemon result for srv_b differs from serial");
    assert!(durability(&res_a, "appended") > 0, "job journaled nothing");
    assert_eq!(durability(&res_a, "replayed"), 0, "fresh job replayed a journal");

    // progress streamed: phase barriers and journal append points
    assert!(
        ev_a.iter().any(|e| e.contains("\"phase\"")),
        "no phase events for srv_a: {ev_a:?}"
    );
    assert!(
        ev_a.iter().any(|e| e.contains("\"barrier\"")),
        "no journal-barrier events for srv_a: {ev_a:?}"
    );
    assert!(ev_b.iter().any(|e| e.contains("\"phase\"")), "no phase events for srv_b");

    // the two jobs interleaved phase-by-phase on the one fleet
    let st = c.status().unwrap();
    let log = sched_log(&st);
    let first_b = log
        .iter()
        .position(|s| s.starts_with(&format!("{idb}:")))
        .expect("job b never scheduled");
    let last_a = log
        .iter()
        .rposition(|s| s.starts_with(&format!("{ida}:")))
        .expect("job a never scheduled");
    assert!(first_b < last_a, "jobs ran serially, no interleave: {log:?}");

    // both models parked warm; a resubmission opens zero new handles
    let warm = st.req("warm_models").unwrap().to_string();
    assert!(
        warm.contains("srv_a") && warm.contains("srv_b"),
        "models not kept warm: {warm}"
    );
    let opens_before = model_opens(&st);
    let id3 = c.submit("srv_a", &policy).unwrap();
    let res3 = connect(&sock).watch(id3, |_| {}).unwrap();
    assert_eq!(result_text(&res3), base_a, "warm-model rerun differs");
    let opens_after = model_opens(&c.status().unwrap());
    assert_eq!(
        opens_after, opens_before,
        "warm-model job re-opened (recompiled) model handles"
    );

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
    assert!(!sock.exists(), "socket file left behind after shutdown");
    let stranded: Vec<String> = std::fs::read_dir(dir.join("mpqd"))
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".mpqj") || n.contains(".tmp."))
        .collect();
    assert!(stranded.is_empty(), "stranded files after clean shutdown: {stranded:?}");
}

#[test]
fn killed_daemon_restarts_and_resumes_from_journal() {
    const CRASH_AT: u64 = 5;
    let dir = zoo_dir("crash");
    let policy = small_policy();
    let base = run_local(&dir, "srv_a", &policy, 0, None).unwrap().to_string();

    // clean daemon run first: learn the job's total barrier count
    let sock1 = dir.join("d1.sock");
    let h1 = spawn_daemon(cfg(&dir, &sock1, &dir.join("mpqd1")));
    let mut c1 = connect(&sock1);
    let id = c1.submit("srv_a", &policy).unwrap();
    let res = connect(&sock1).watch(id, |_| {}).unwrap();
    assert_eq!(result_text(&res), base);
    let total = durability(&res, "appended");
    assert!(total > CRASH_AT, "need more than {CRASH_AT} barriers, got {total}");
    c1.shutdown().unwrap();
    h1.join().unwrap().unwrap();

    // kill the daemon mid-job at journal barrier CRASH_AT
    let sock2 = dir.join("d2.sock");
    let state2 = dir.join("mpqd2");
    let mut crash_cfg = cfg(&dir, &sock2, &state2);
    crash_cfg.fault_plan = Some(format!("crash@PHASE:{CRASH_AT}"));
    let h2 = spawn_daemon(crash_cfg);
    let mut c2 = connect(&sock2);
    let jid = c2.submit("srv_a", &policy).unwrap();
    let err = h2.join().expect_err("daemon survived its crash barrier");
    let msg = err
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| err.downcast_ref::<&str>().copied())
        .unwrap_or("<non-string panic>");
    assert!(msg.contains("crash@PHASE"), "unexpected panic: {msg}");
    assert!(
        state2.join(format!("job_{jid}.mpqj")).exists(),
        "job journal missing after the kill"
    );

    // restart on the same state dir: the job auto-resumes, replays the
    // CRASH_AT durable units and recomputes exactly the remainder
    let h3 = spawn_daemon(cfg(&dir, &sock2, &state2));
    let resumed = connect(&sock2).watch(jid, |_| {}).unwrap();
    assert_eq!(result_text(&resumed), base, "resumed result differs from serial");
    assert_eq!(durability(&resumed, "replayed"), CRASH_AT, "replayed unit count");
    assert_eq!(
        durability(&resumed, "appended"),
        total - CRASH_AT,
        "completed units were re-executed after restart"
    );
    let mut c3 = connect(&sock2);
    c3.shutdown().unwrap();
    h3.join().unwrap().unwrap();
}

#[test]
fn admission_cap_and_cancel_leave_nothing_stranded() {
    let dir = zoo_dir("adm");
    let policy = small_policy();
    let sock = dir.join("d.sock");
    let state = dir.join("mpqd");
    let mut dc = cfg(&dir, &sock, &state);
    dc.workers = 1;
    dc.max_idle = 0;
    dc.max_jobs = 2;
    dc.hold = true;
    let h = spawn_daemon(dc);
    let mut c = connect(&sock);

    let id1 = c.submit("srv_a", &policy).unwrap();
    let id2 = c.submit("srv_b", &policy).unwrap();
    let err = c.submit("srv_a", &policy).unwrap_err();
    assert!(
        format!("{err:#}").contains("admission refused"),
        "expected an admission error, got: {err:#}"
    );
    assert!(c.submit("nope_model", &policy).is_err(), "unknown model admitted");

    // cancel frees the residency slot; a second cancel is refused
    c.cancel(id2).unwrap();
    let id3 = c.submit("srv_b", &policy).unwrap();
    assert!(c.cancel(id2).is_err(), "double cancel succeeded");

    c.release().unwrap();
    let base_a = run_local(&dir, "srv_a", &policy, 0, None).unwrap().to_string();
    let base_b = run_local(&dir, "srv_b", &policy, 0, None).unwrap().to_string();
    let r1 = connect(&sock).watch(id1, |_| {}).unwrap();
    let r3 = connect(&sock).watch(id3, |_| {}).unwrap();
    assert_eq!(result_text(&r1), base_a);
    assert_eq!(result_text(&r3), base_b);

    let st = c.status().unwrap();
    let j2 = st
        .req("jobs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|j| j.req("id").unwrap().as_f64().unwrap() as u64 == id2)
        .expect("cancelled job fell out of the table");
    assert_eq!(j2.req("state").unwrap().as_str().unwrap(), "cancelled");

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
    assert!(!sock.exists(), "socket file left behind");
    let stranded: Vec<String> = std::fs::read_dir(&state)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".mpqj") || n.contains(".tmp."))
        .collect();
    assert!(stranded.is_empty(), "cancel/shutdown stranded files: {stranded:?}");
}

/// Socket claiming: a second daemon pointed at a **live** daemon's
/// socket must refuse to start (a blind unlink would strand the first
/// daemon's clients on a dead inode); a live listener that is not mpqd
/// is refused too; only a genuinely stale socket file — nothing
/// accepting behind it — is unlinked and rebound.
#[test]
fn second_daemon_on_a_live_socket_is_refused() {
    let dir = zoo_dir("claim");
    let sock = dir.join("d.sock");
    let h = spawn_daemon(cfg(&dir, &sock, &dir.join("mpqd")));
    let mut c = connect(&sock);

    // a second daemon on the same socket: refused, and the error says why
    let err = daemon::run(cfg(&dir, &sock, &dir.join("mpqd_b")))
        .expect_err("second daemon started on a live socket");
    let msg = format!("{err:#}");
    assert!(msg.contains("live mpqd"), "refusal must name the live daemon: {msg}");

    // the first daemon is unharmed — same socket, still answering
    assert!(sock.exists(), "refused start unlinked the live socket");
    c.status().expect("first daemon stopped answering after the refused start");
    c.shutdown().unwrap();
    h.join().unwrap().unwrap();

    // a live listener that is NOT mpqd (accepts, never handshakes): the
    // claim probe times out on the handshake and refuses to unlink it
    let squatter = std::os::unix::net::UnixListener::bind(&sock).unwrap();
    let err = daemon::run(cfg(&dir, &sock, &dir.join("mpqd_c")))
        .expect_err("daemon started over a foreign listener");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("does not speak"),
        "refusal must name the protocol mismatch: {msg}"
    );
    assert!(sock.exists(), "foreign live socket was unlinked");
    drop(squatter);

    // now the file is stale (nothing accepting): claimed and rebound
    let h2 = spawn_daemon(cfg(&dir, &sock, &dir.join("mpqd_d")));
    let mut c2 = connect(&sock);
    c2.shutdown().unwrap();
    h2.join().unwrap().unwrap();
    assert!(!sock.exists());
}

fn job_subscribers(status: &mpq::jsonio::Json, id: u64) -> u64 {
    status
        .req("jobs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|j| j.req("id").unwrap().as_f64().unwrap() as u64 == id)
        .expect("job missing from the status table")
        .req("subscribers")
        .unwrap()
        .as_f64()
        .unwrap() as u64
}

/// Subscriber-leak regression: a `watch` client that disconnects without
/// its job reaching a terminal state must not park its channel (and every
/// queued frame) on the job forever.  `Status` probes the fan-out list;
/// the dropped watcher's connection thread notices its dead socket and
/// exits, and the next probe prunes the channel — the count observably
/// returns to zero while the job is still resident.
#[test]
fn dropped_watcher_is_pruned_from_subscribers() {
    use mpq::jsonio::Json;
    use mpq::serve::proto::{self, msg};

    let dir = zoo_dir("subs");
    let sock = dir.join("d.sock");
    let mut dc = cfg(&dir, &sock, &dir.join("mpqd"));
    dc.hold = true; // keep the job resident (queued) for the whole test
    let h = spawn_daemon(dc);
    let mut c = connect(&sock);
    let id = c.submit("srv_a", &small_policy()).unwrap();

    // raw subscription so the test controls the connection's lifetime
    let mut s = std::os::unix::net::UnixStream::connect(&sock).unwrap();
    proto::handshake(&mut s).unwrap();
    proto::send(&mut s, msg::SUBSCRIBE, id, &Json::Null).unwrap();
    let (kind, _, _) = proto::recv(&mut s).unwrap().expect("subscribe ack");
    assert_eq!(kind, msg::ACK, "subscribe refused");
    assert_eq!(
        job_subscribers(&c.status().unwrap(), id),
        1,
        "subscription never landed"
    );

    // hang up without cancelling; the daemon must notice on its own
    drop(s);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        // detection is two-phase (probe wakes the conn thread, the next
        // probe reaps the dropped channel), hence the bounded poll
        if job_subscribers(&c.status().unwrap(), id) == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "dropped watcher still subscribed after 10s — fan-out leak"
        );
        thread::sleep(Duration::from_millis(20));
    }

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
}

#[test]
fn priority_runs_first_then_equals_round_robin() {
    let dir = zoo_dir("prio");
    let policy = small_policy();
    let hi = JobPolicy { priority: 9, ..policy.clone() };
    let sock = dir.join("d.sock");
    let mut dc = cfg(&dir, &sock, &dir.join("mpqd"));
    dc.workers = 1;
    dc.max_jobs = 8;
    dc.hold = true;
    let h = spawn_daemon(dc);
    let mut c = connect(&sock);

    let a = c.submit("srv_a", &policy).unwrap();
    let b = c.submit("srv_b", &policy).unwrap();
    let p = c.submit("srv_b", &hi).unwrap();
    c.release().unwrap();

    let deadline = Instant::now() + Duration::from_secs(120);
    let log: Vec<String> = loop {
        let st = c.status().unwrap();
        let done = st
            .req("jobs")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .all(|j| j.req("state").unwrap().as_str().unwrap() == "done");
        if done {
            break sched_log(&st);
        }
        assert!(Instant::now() < deadline, "jobs never finished");
        thread::sleep(Duration::from_millis(20));
    };

    // the priority-9 job owns the schedule for all four of its phases
    for (i, entry) in log.iter().take(4).enumerate() {
        assert!(
            entry.starts_with(&format!("{p}:")),
            "step {i} went to {entry}, not the priority job: {log:?}"
        );
    }
    // the equal-priority pair round-robins phase by phase
    assert!(
        log[4].starts_with(&format!("{a}:"))
            && log[5].starts_with(&format!("{b}:"))
            && log[6].starts_with(&format!("{a}:")),
        "equal-priority jobs did not round-robin: {log:?}"
    );

    c.shutdown().unwrap();
    h.join().unwrap().unwrap();
}
