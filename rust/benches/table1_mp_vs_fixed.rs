//! `cargo bench --bench table1_mp_vs_fixed` — regenerates Table 1: MP vs fixed precision
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("table1_mp_vs_fixed", "Table 1: MP vs fixed precision") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::table1(&opts).expect("table1");
    tab.print();
    tab.save(mpq::report::results_dir(), "table1").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
