//! `cargo bench --bench table3_bert_glue` — regenerates Table 3: BERT GLUE-style tasks
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("table3_bert_glue", "Table 3: BERT GLUE-style tasks") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::table3(&opts).expect("table3");
    tab.print();
    tab.save(mpq::report::results_dir(), "table3").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
