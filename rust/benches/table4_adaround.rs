//! `cargo bench --bench table4_adaround` — regenerates Table 4: AdaRound-integrated MP
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("table4_adaround", "Table 4: AdaRound-integrated MP") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::table4(&opts).expect("table4");
    tab.print();
    tab.save(mpq::report::results_dir(), "table4").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
