//! `cargo bench --bench microbench` — hot-path microbenchmarks used by the
//! §Perf pass: forward-pass latency per configuration, qparam
//! materialization, config-buffer upload, SQNR aggregation, flip-sequence
//! construction, the host-side quantization substrate, the end-to-end
//! engine paths (full Phase-1 sweep, Phase-2 binary search), and the
//! multi-client `EvalPool` sweep at 1/2/4 workers
//! (`phase1_pool/full_sensitivity_sweep_wN` — the cross-PR speedup gate
//! compares w4 against w1).
//!
//! Results are also written to `BENCH_microbench.json` so before/after
//! speedups are tracked across PRs (`scripts/bench_compare` fails CI on
//! >20% regression of the gated entries against the committed baseline).

use mpq::bench::{bench, bench_result, BenchResult};
use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::groups::Lattice;
use mpq::model::QuantConfig;
use mpq::quant;
use mpq::sensitivity;
use mpq::tensor::Tensor;
use std::collections::HashMap;

fn main() {
    if !mpq::bench::preamble("microbench", "hot-path microbenchmarks") {
        return;
    }
    let mut results: Vec<BenchResult> = Vec::new();
    let mut pipe = Pipeline::open(mpq::artifacts_dir(), "resnet_s").expect("open resnet_s");
    pipe.calibrate(256, 0).expect("calibrate");

    let entry = pipe.model.entry.clone();
    let cfg = QuantConfig::fixed(&entry, 8, 8);
    let cb = pipe.model.config_buffers(&cfg, &HashMap::new()).unwrap();

    // L3→PJRT: single quantized forward (the dominant cost of everything)
    {
        let set = pipe.calib_set().unwrap();
        let xb = &set.batches[0];
        results.push(bench_result("forward/one_batch_w8a8", 3, 20, || {
            pipe.model.forward(xb, &cb).map(|_| ())
        }));
    }

    // Phase-1 probe: one (g, c) streamed against the cached FP reference
    {
        let set = pipe.calib_set().unwrap();
        let ev = mpq::engine::Evaluator::new(&pipe.model, set);
        results.push(bench_result("phase1/sqnr_probe_256imgs", 1, 5, || {
            let pcfg = sensitivity::probe_config(
                &pipe.model.entry,
                1,
                mpq::groups::Candidate::new(8, 8),
            );
            ev.sqnr(&pcfg, &HashMap::new()).map(|_| ())
        }));
    }

    // Phase-1: the full sensitivity sweep through the engine (reference
    // cached after warmup — steady-state `probes × sweep` cost)
    {
        let lat = Lattice::practical();
        results.push(bench_result("phase1/full_sensitivity_sweep", 1, 3, || {
            pipe.sensitivity_sqnr(&lat).map(|_| ())
        }));
    }

    // config materialization (host-side row patching, should be ≪ forward)
    results.push(bench("config/qparam_tensors", 10, 200, || {
        let _ = pipe.model.qparam_tensors(&cfg).unwrap();
    }));
    results.push(bench("config/buffers_upload", 5, 50, || {
        let _ = pipe.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    }));

    // quant substrate: MSE weight-scale search on the largest conv
    {
        let wq = entry
            .w_quantizers
            .iter()
            .max_by_key(|q| pipe.model.weights[q.param_idx].numel())
            .unwrap();
        let w = pipe.model.weights[wq.param_idx].clone();
        let ratios = quant::default_ratios();
        results.push(bench("quant/weight_scales_mse_largest", 2, 20, || {
            let _ = quant::weight_scales_mse(&w, wq.channels, wq.channel_axis, 8, &ratios)
                .unwrap();
        }));
    }

    // act-range grid accumulation (host side of calibration)
    {
        let mut ar = quant::ActRanges::new(1, vec![4, 6, 8, 16], quant::default_ratios());
        let mut rng = mpq::util::Rng::new(1);
        let data: Vec<f32> = (0..131072).map(|_| rng.f64() as f32 * 4.0 - 1.0).collect();
        let t = Tensor::from_f32(&[131072], data).unwrap();
        results.push(bench("quant/act_grid_accumulate_131k", 2, 20, || {
            ar.accumulate(std::slice::from_ref(&t), 1).unwrap();
        }));
    }

    // Phase-2 ledger walk (pure host arithmetic)
    {
        let lat = Lattice::practical();
        let sens = pipe.sensitivity_sqnr(&lat).unwrap();
        results.push(bench("phase2/flip_sequence", 10, 1000, || {
            let _ = pipe.flips(&lat, &sens);
        }));
    }

    // SQNR aggregation on host logits
    {
        let set = pipe.calib_set().unwrap();
        let fp = sensitivity::fp_logits(&pipe.model, set).unwrap();
        results.push(bench("metrics/sqnr_db_2048x10", 5, 200, || {
            let _ = sensitivity::sqnr_db(&fp, &fp).unwrap();
        }));
    }

    // Phase-2: binary accuracy-target search end-to-end (memoized finish)
    {
        let lat = Lattice::practical();
        let sens = pipe.sensitivity_sqnr(&lat).unwrap();
        let flips = pipe.flips(&lat, &sens);
        let fp = pipe.eval_fp32().unwrap();
        let target = fp - 0.02;
        results.push(bench_result("phase2/binary_search", 1, 5, || {
            pipe.search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
                .map(|_| ())
        }));
    }

    // Phase-1 sweep through the EvalPool at 1/2/4 workers.  Each pipeline
    // gets its own pool (N private PJRT clients + eval-set shards); the
    // pool's probe memo is cleared inside the timed closure (O(probes)
    // host work, negligible) so every iteration measures a real sweep
    // rather than cache hits.  The 1-worker pool is the baseline the
    // acceptance gate compares w4 against — same dispatch overhead, no
    // shard parallelism.
    {
        let lat = Lattice::practical();
        for workers in [1usize, 2, 4] {
            let mut pp =
                Pipeline::open(mpq::artifacts_dir(), "resnet_s").expect("open resnet_s");
            pp.enable_pool(workers).expect("spawn eval pool");
            pp.calibrate(256, 0).expect("calibrate");
            let name = format!("phase1_pool/full_sensitivity_sweep_w{workers}");
            results.push(bench_result(&name, 1, 3, || {
                pp.clear_eval_memo();
                pp.sensitivity_sqnr(&lat).map(|_| ())
            }));
        }
    }

    mpq::bench::write_json("BENCH_microbench.json", "microbench", &results)
        .expect("write BENCH_microbench.json");
    println!("wrote BENCH_microbench.json ({} entries)", results.len());
}
