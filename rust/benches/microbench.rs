//! `cargo bench --bench microbench` — hot-path microbenchmarks used by the
//! §Perf pass: forward-pass latency per configuration, qparam
//! materialization, config-buffer upload, SQNR aggregation, flip-sequence
//! construction, and the host-side quantization substrate.

use mpq::bench::{bench, bench_result};
use mpq::coordinator::Pipeline;
use mpq::groups::Lattice;
use mpq::model::QuantConfig;
use mpq::quant;
use mpq::sensitivity;
use mpq::tensor::Tensor;
use std::collections::HashMap;

fn main() {
    if !mpq::bench::preamble("microbench", "hot-path microbenchmarks") {
        return;
    }
    let mut pipe = Pipeline::open(mpq::artifacts_dir(), "resnet_s").expect("open resnet_s");
    pipe.calibrate(256, 0).expect("calibrate");

    let entry = pipe.model.entry.clone();
    let cfg = QuantConfig::fixed(&entry, 8, 8);
    let cb = pipe.model.config_buffers(&cfg, &HashMap::new()).unwrap();

    // L3→PJRT: single quantized forward (the dominant cost of everything)
    {
        let set = pipe.calib_set().unwrap();
        let xb = &set.batches[0];
        bench_result("forward/one_batch_w8a8", 3, 20, || {
            pipe.model.forward(xb, &cb).map(|_| ())
        });
    }

    // Phase-1 probe: full SQNR pass over the calib set for one (g, c)
    {
        let set = pipe.calib_set().unwrap();
        let fp = sensitivity::fp_logits(&pipe.model, set).unwrap();
        bench("phase1/sqnr_probe_256imgs", 1, 5, || {
            let pcfg = sensitivity::probe_config(&pipe.model, 1, mpq::groups::Candidate::new(8, 8));
            let pcb = pipe.model.config_buffers(&pcfg, &HashMap::new()).unwrap();
            let q = pipe.model.logits_on(set, &pcb).unwrap();
            let _ = sensitivity::sqnr_db(&fp, &q).unwrap();
        });
    }

    // config materialization (host-side, should be ≪ forward)
    bench("config/qparam_tensors", 10, 200, || {
        let _ = pipe.model.qparam_tensors(&cfg).unwrap();
    });
    bench("config/buffers_upload", 5, 50, || {
        let _ = pipe.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    });

    // quant substrate: MSE weight-scale search on the largest conv
    {
        let wq = entry
            .w_quantizers
            .iter()
            .max_by_key(|q| pipe.model.weights[q.param_idx].numel())
            .unwrap();
        let w = pipe.model.weights[wq.param_idx].clone();
        let ratios = quant::default_ratios();
        bench("quant/weight_scales_mse_largest", 2, 20, || {
            let _ = quant::weight_scales_mse(&w, wq.channels, wq.channel_axis, 8, &ratios)
                .unwrap();
        });
    }

    // act-range grid accumulation (host side of calibration)
    {
        let mut ar = quant::ActRanges::new(1, vec![4, 6, 8, 16], quant::default_ratios());
        let mut rng = mpq::util::Rng::new(1);
        let data: Vec<f32> = (0..131072).map(|_| rng.f64() as f32 * 4.0 - 1.0).collect();
        let t = Tensor::from_f32(&[131072], data).unwrap();
        bench("quant/act_grid_accumulate_131k", 2, 20, || {
            ar.accumulate(std::slice::from_ref(&t), 1).unwrap();
        });
    }

    // Phase-2 ledger walk (pure host arithmetic)
    {
        let lat = Lattice::practical();
        let sens = pipe.sensitivity_sqnr(&lat).unwrap();
        bench("phase2/flip_sequence", 10, 1000, || {
            let _ = pipe.flips(&lat, &sens);
        });
    }

    // SQNR aggregation on host logits
    {
        let set = pipe.calib_set().unwrap();
        let fp = sensitivity::fp_logits(&pipe.model, set).unwrap();
        bench("metrics/sqnr_db_2048x10", 5, 200, || {
            let _ = sensitivity::sqnr_db(&fp, &fp).unwrap();
        });
    }
}
