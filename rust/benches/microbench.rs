//! `cargo bench --bench microbench` — hot-path microbenchmarks used by the
//! §Perf pass: forward-pass latency per configuration, qparam
//! materialization, config-buffer upload, SQNR aggregation, flip-sequence
//! construction, the host-side quantization substrate, the end-to-end
//! engine paths (full Phase-1 sweep, Phase-2 binary search), and the
//! multi-client `EvalPool` sweep at 1/2/4 workers.
//!
//! Two sections, one JSON:
//!
//! * **sim section (always runs, hermetic)** — a generated sim-backend zoo
//!   (`mpq::sim`) sized so probe compute dominates dispatch, producing
//!   `phase1_sim/...`, `phase2_sim/...`,
//!   `phase1_pool_sim/full_sensitivity_sweep_w{1,2,4}`, the daemon's
//!   `serve_sim/submit_roundtrip_p{50,90,99}`, the process-lane IPC
//!   substrate `ipc_sim/roundtrip_{1k,64k,1m}_p{50,90,99}` and the
//!   subprocess-fleet sweep `phase1_proc_sim/full_sensitivity_sweep_w{1,4}`
//!   on every machine, toolchain-only.  These are the entries
//!   `scripts/bench_compare` gates on in CI — including the pool w4-vs-w1
//!   and process-lane w4-vs-w1 speedup checks — so the gate is no longer
//!   vacuous without PJRT artifacts.
//! * **PJRT section (artifacts-gated)** — the original `resnet_s` entries
//!   (`phase1/...`, `phase2/...`, `phase1_pool/..._wN`), skipped without
//!   `make artifacts`.
//!
//! Results land in `BENCH_microbench.json`; CI diffs against the committed
//! repo-root baseline (>20% regression on gated entries fails the build).

use mpq::adaround::AdaRoundCfg;
use mpq::bench::{bench, bench_result, BenchResult};
use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::groups::Lattice;
use mpq::model::QuantConfig;
use mpq::pool::{EvalFleet, EvalPool, ProbeKind, CALIB_SET};
use mpq::quant;
use mpq::sensitivity::{self, Metric};
use mpq::sim::{self, SimSpec};
use mpq::tensor::Tensor;
use std::collections::HashMap;

fn main() {
    println!("### bench microbench — hot-path microbenchmarks");
    let mut results: Vec<BenchResult> = Vec::new();
    sim_benches(&mut results);
    if cfg!(feature = "pjrt") && mpq::artifacts_dir().join("manifest.json").exists() {
        pjrt_benches(&mut results);
    } else {
        println!(
            "no PJRT backend or no AOT artifacts at {} — PJRT entries skipped \
             (the sim entries above are the hermetic gate)",
            mpq::artifacts_dir().display()
        );
    }
    mpq::bench::write_json("BENCH_microbench.json", "microbench", &results)
        .expect("write BENCH_microbench.json");
    println!("wrote BENCH_microbench.json ({} entries)", results.len());
}

/// Hermetic end-to-end benches on the sim backend.  The model is sized so
/// each probe is real compute (≫ pool dispatch overhead): d = 128→160→
/// 160→10 over 512 calibration samples = 64 batches per probe sweep.
fn sim_benches(results: &mut Vec<BenchResult>) {
    let dir = std::env::temp_dir().join("mpq_microbench_sim");
    std::fs::remove_dir_all(&dir).ok();
    let spec = SimSpec {
        dims: vec![128, 160, 160, 10],
        calib_n: 512,
        val_n: 256,
        ood_n: 0,
        ..Default::default()
    };
    sim::generate(&dir, &spec).expect("generate sim artifacts");
    let lat = Lattice::practical();

    let mut pipe = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
    pipe.calibrate(spec.calib_n, 0).expect("calibrate");
    results.push(bench_result("phase1_sim/full_sensitivity_sweep", 1, 3, || {
        pipe.sensitivity_sqnr(&lat).map(|_| ())
    }));

    // Journal overhead: the same serial sweep, but with a fresh run
    // journal appended at every probe barrier (each iteration reopens the
    // journal non-resumed so all probes record, none skip).  CI's
    // bench_compare gates this against the plain sweep above: durability
    // must cost <5% of Phase-1 wall time.
    {
        let jpath = dir.join("bench_journal.mpqj");
        let mut pj = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pj.calibrate(spec.calib_n, 0).expect("calibrate");
        results.push(bench_result("resume_sim/journal_overhead", 1, 3, || {
            let stats = std::rc::Rc::new(mpq::store::StoreStats::default());
            let j = mpq::store::RunJournal::open(&jpath, false, stats)?;
            pj.set_journal(Some(std::rc::Rc::new(j)));
            pj.sensitivity_sqnr(&lat).map(|_| ())
        }));
    }

    pipe.limit_val(spec.val_n, 7).expect("limit val");
    let sens = pipe.sensitivity_sqnr(&lat).expect("phase 1");
    let flips = pipe.flips(&lat, &sens);
    let fp = pipe.eval_fp32().expect("fp32");
    let target = fp - 0.02;
    results.push(bench_result("phase2_sim/binary_search", 1, 5, || {
        pipe.search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
            .map(|_| ())
    }));

    // Phase-1 sweep through the EvalPool at 1/2/4 workers on the sim
    // backend — the hermetic half of the pool speedup gate.  The memo is
    // cleared inside the timed closure so every iteration measures a real
    // sweep; the 1-worker pool is the baseline (same dispatch overhead, no
    // shard parallelism).
    for workers in [1usize, 2, 4] {
        let mut pp = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pp.enable_pool(workers).expect("spawn eval pool");
        pp.calibrate(spec.calib_n, 0).expect("calibrate");
        let name = format!("phase1_pool_sim/full_sensitivity_sweep_w{workers}");
        results.push(bench_result(&name, 1, 3, || {
            pp.clear_eval_memo();
            pp.sensitivity_sqnr(&lat).map(|_| ())
        }));
    }

    // Self-healing overhead: the same w4 sweep with a *recurring* injected
    // panic (one lane dies every 5th probe it serves, respawned each time;
    // budget sized so the fleet never degrades).  Gated against the plain
    // w1 sweep: supervised-and-dying w4 must still beat serial — respawn +
    // state replay + requeue are bounded overhead, not a cliff.
    {
        let plan = mpq::pool::FaultPlan::parse("panic@1:5*,budget:64,backoff:0")
            .expect("bench fault plan");
        let fleet = EvalFleet::with_faults(&dir, 4, plan).expect("spawn faulted fleet");
        let mut pp = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pp.attach_fleet(&fleet).expect("attach faulted fleet");
        pp.calibrate(spec.calib_n, 0).expect("calibrate");
        results.push(bench_result(
            "phase1_pool_sim_faulty/full_sensitivity_sweep_w4",
            1,
            3,
            || {
                pp.clear_eval_memo();
                pp.sensitivity_sqnr(&lat).map(|_| ())
            },
        ));
        let fs = fleet.failure_stats();
        assert!(
            fs.worker_restarts > 0 && fs.jobs_requeued > 0,
            "faulted bench must actually exercise the supervisor: {fs:?}"
        );
        assert!(
            fs.degraded_events.is_empty(),
            "faulted bench must stay within its restart budget: {:?}",
            fs.degraded_events
        );
    }

    // Pooled FIT sensitivity at 1/4 workers: shard-parallel grad²/err²
    // accumulation through the fleet (FIT has no memo — every iteration
    // is a full accumulation sweep).
    for workers in [1usize, 4] {
        let mut pp = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pp.enable_pool(workers).expect("spawn eval pool");
        pp.calibrate(spec.calib_n, 0).expect("calibrate");
        let name = format!("fit_pool_sim_w{workers}");
        results.push(bench_result(&name, 1, 3, || {
            pp.sensitivity(&lat, Metric::Fit, None).map(|_| ())
        }));
    }

    // Pooled AdaRound at 1/4 workers: the (layer × wbits) jobs anneal
    // round-robin across the fleet; taps capture stays on the driver's
    // client and is amortized by the job compute.
    let ar_cfg = AdaRoundCfg { steps: 40, ..Default::default() };
    for workers in [1usize, 4] {
        let mut pp = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pp.enable_pool(workers).expect("spawn eval pool");
        pp.calibrate(spec.calib_n, 0).expect("calibrate");
        let name = format!("adaround_pool_sim_w{workers}");
        results.push(bench_result(&name, 1, 3, || {
            pp.adaround(&lat, &ar_cfg).map(|_| ())
        }));
    }

    fleet_reuse_bench(results);
    serve_submit_bench(results);
    ipc_bench(results);
    proc_fleet_bench(results);
    chaos_heartbeat_bench(results);
}

/// Process-lane IPC substrate latency: one MPQJ frame down a Unix socket
/// pair, echoed back by a peer thread (`store::read_frame` →
/// `store::write_frame`, the exact framing `pool/transport.rs` rides),
/// at the control-plane size (1 KiB), the bulk threshold (64 KiB ≫ the
/// 16 KiB control/bulk cutoff) and a full activation-shard-sized payload
/// (1 MiB).  Reported as p50/p90/p99 per size, same percentile encoding
/// as the serve entries.
fn ipc_bench(results: &mut Vec<BenchResult>) {
    use std::os::unix::net::UnixStream;

    const N: usize = 200;
    const MAX: usize = 1 << 30; // the transport's MAX_IPC_FRAME
    let (mut a, b) = UnixStream::pair().expect("socketpair");
    let echo = std::thread::spawn(move || {
        let mut b = b;
        while let Ok(Some(rec)) = mpq::store::read_frame(&mut b, MAX) {
            if mpq::store::write_frame(&mut b, rec.kind, rec.digest, &rec.payload).is_err() {
                break;
            }
        }
    });

    for (tag, size) in [("1k", 1usize << 10), ("64k", 64 << 10), ("1m", 1 << 20)] {
        let payload = vec![0xA5u8; size];
        let mut roundtrip = |i: u64| {
            mpq::store::write_frame(&mut a, 64, i, &payload).expect("ipc write");
            let rec = mpq::store::read_frame(&mut a, MAX).expect("ipc read").expect("ipc eof");
            assert_eq!(rec.payload.len(), size, "echo garbled the frame");
        };
        for i in 0..8 {
            roundtrip(i); // warmup
        }
        let mut lat = Vec::with_capacity(N);
        for i in 0..N {
            let t0 = std::time::Instant::now();
            roundtrip(i as u64);
            lat.push(t0.elapsed().as_secs_f64());
        }
        lat.sort_by(f64::total_cmp);
        for (ptag, q) in [("p50", 0.50), ("p90", 0.90), ("p99", 0.99)] {
            let v = lat[((N as f64 * q) as usize).min(N - 1)];
            let r = BenchResult {
                name: format!("ipc_sim/roundtrip_{tag}_{ptag}"),
                min_s: v,
                mean_s: v,
                max_s: v,
                iters: N,
            };
            r.print();
            results.push(r);
        }
    }
    drop(a); // EOF ends the echo loop
    echo.join().expect("echo thread");
}

/// Phase-1 sweep through **process-backed** worker lanes
/// (`EvalFleet::new_proc` → `mpq worker` subprocesses over the socket
/// transport) at 1 and 4 lanes — the distributed counterpart of the
/// `phase1_pool_sim` entries.  `bench_compare` gates w1 >= 1.2x w4 live:
/// four processes must beat one despite tensors crossing process
/// boundaries, or the transport has become the bottleneck.
fn proc_fleet_bench(results: &mut Vec<BenchResult>) {
    std::env::set_var("MPQ_WORKER_BIN", env!("CARGO_BIN_EXE_mpq"));
    let dir = std::env::temp_dir().join("mpq_microbench_proc");
    std::fs::remove_dir_all(&dir).ok();
    let spec = SimSpec {
        dims: vec![128, 160, 160, 10],
        calib_n: 512,
        val_n: 256,
        ood_n: 0,
        ..Default::default()
    };
    sim::generate(&dir, &spec).expect("generate proc sim artifacts");
    let lat = Lattice::practical();
    for workers in [1usize, 4] {
        let fleet = EvalFleet::new_proc(&dir, workers).expect("spawn proc fleet");
        let mut pp = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pp.attach_fleet(&fleet).expect("attach proc fleet");
        pp.calibrate(spec.calib_n, 0).expect("calibrate");
        let name = format!("phase1_proc_sim/full_sensitivity_sweep_w{workers}");
        results.push(bench_result(&name, 1, 3, || {
            pp.clear_eval_memo();
            pp.sensitivity_sqnr(&lat).map(|_| ())
        }));
        assert_eq!(
            fleet.failure_stats().worker_restarts,
            0,
            "proc bench must run clean — a dying lane poisons the timing"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Heartbeat overhead: the `phase1_proc_sim` w4 sweep again, but with an
/// aggressive 25 ms ping interval (10× the default rate) so the PING/PONG
/// traffic and the per-frame wire seam are maximally present in the timed
/// window.  `bench_compare` gates this against the plain w4 sweep
/// (`--speedup ...w4:chaos_sim/heartbeat_overhead:0.95`): liveness must
/// cost under ~5% of Phase-1 wall time or the chaos hardening regressed
/// the hot path.
fn chaos_heartbeat_bench(results: &mut Vec<BenchResult>) {
    std::env::set_var("MPQ_WORKER_BIN", env!("CARGO_BIN_EXE_mpq"));
    std::env::set_var("MPQ_HEARTBEAT_MS", "25");
    let dir = std::env::temp_dir().join("mpq_microbench_chaos");
    std::fs::remove_dir_all(&dir).ok();
    let spec = SimSpec {
        dims: vec![128, 160, 160, 10],
        calib_n: 512,
        val_n: 256,
        ood_n: 0,
        ..Default::default()
    };
    sim::generate(&dir, &spec).expect("generate chaos sim artifacts");
    let lat = Lattice::practical();
    {
        let fleet = EvalFleet::new_proc(&dir, 4).expect("spawn proc fleet");
        let mut pp = Pipeline::open(&dir, &spec.name).expect("open sim zoo");
        pp.attach_fleet(&fleet).expect("attach proc fleet");
        pp.calibrate(spec.calib_n, 0).expect("calibrate");
        results.push(bench_result("chaos_sim/heartbeat_overhead", 1, 3, || {
            pp.clear_eval_memo();
            pp.sensitivity_sqnr(&lat).map(|_| ())
        }));
        assert_eq!(
            fleet.failure_stats().worker_restarts,
            0,
            "heartbeat bench must run clean — a liveness death poisons the timing"
        );
        assert!(
            fleet.wire_counters().heartbeats_sent > 0,
            "pings must actually flow while the sweep is timed"
        );
    }
    std::env::remove_var("MPQ_HEARTBEAT_MS");
    std::fs::remove_dir_all(&dir).ok();
}

/// Fleet-reuse entry: attach-and-probe a *second* model on a fleet that is
/// already warm — measures the marginal cost of model sharing (no thread
/// respawn, no recompilation; the post-loop assert makes the zero-compile
/// claim a hard failure, not just a timing).
fn fleet_reuse_bench(results: &mut Vec<BenchResult>) {
    let dir = std::env::temp_dir().join("mpq_microbench_fleet");
    std::fs::remove_dir_all(&dir).ok();
    let spec_a = SimSpec {
        dims: vec![64, 96, 10],
        calib_n: 128,
        val_n: 64,
        ood_n: 0,
        ..Default::default()
    };
    let spec_b = SimSpec {
        name: "sim_mlp_b".into(),
        dims: vec![64, 96, 10],
        calib_n: 128,
        val_n: 64,
        ood_n: 0,
        seed: 13,
        ..Default::default()
    };
    sim::generate_zoo(&dir, &[spec_a.clone(), spec_b.clone()]).expect("generate fleet zoo");
    let fleet = EvalFleet::new(&dir, 2).expect("spawn fleet");
    // warm both models: A via a full sweep, B via attach + calibrate +
    // one probe (compiles B's forward on every worker)
    let mut pa = Pipeline::open(&dir, &spec_a.name).expect("open A");
    pa.attach_fleet(&fleet).expect("attach A");
    pa.calibrate(spec_a.calib_n, 0).expect("calibrate A");
    pa.sensitivity_sqnr(&Lattice::practical()).expect("sweep A");
    let mut pb = Pipeline::open(&dir, &spec_b.name).expect("open B");
    pb.attach_fleet(&fleet).expect("attach B");
    pb.calibrate(spec_b.calib_n, 0).expect("calibrate B");
    let cfg = QuantConfig::fixed(&pb.model.entry, 8, 8);
    let pool_b = pb.pool.as_ref().expect("B pool");
    pool_b
        .submit(CALIB_SET, ProbeKind::Sqnr, &cfg, &HashMap::new())
        .and_then(|h| h.wait())
        .expect("warm B");

    let opens_before = fleet.model_opens();
    results.push(bench_result("fleet_sim/second_model_attach_probe", 1, 5, || {
        // re-attach B (refcount bump on the warm fleet) and run one real
        // probe through the fresh client; memo cleared so the probe is a
        // genuine shard-parallel evaluation, not a cache hit
        fleet.clear_memo();
        let client = EvalPool::attach(&fleet, &spec_b.name)?;
        client
            .submit(CALIB_SET, ProbeKind::Sqnr, &cfg, &HashMap::new())?
            .wait()
            .map(|_| ())
    }));
    assert_eq!(
        fleet.model_opens(),
        opens_before,
        "second-model attach recompiled executables on a warm fleet"
    );
}

/// Daemon control-plane latency: submit→ACK round trips over the Unix
/// socket against a held daemon (`--hold` stages jobs without running
/// them), so the measurement is the wire protocol + admission + fsynced
/// job record — no pipeline compute.  Reported as p50/p90/p99 over the
/// sorted per-submit latencies (one percentile per JSON entry; min/mean/
/// max all carry the percentile so `bench_compare`'s `min_s` basis works
/// unchanged).
fn serve_submit_bench(results: &mut Vec<BenchResult>) {
    use mpq::serve::daemon::{self, ServeCfg};
    use mpq::serve::{Client, JobPolicy};

    let dir = std::env::temp_dir().join("mpq_microbench_serve");
    std::fs::remove_dir_all(&dir).ok();
    let spec = SimSpec {
        dims: vec![8, 10, 6],
        calib_n: 16,
        val_n: 8,
        ood_n: 0,
        ..Default::default()
    };
    sim::generate(&dir, &spec).expect("generate serve sim artifacts");

    const N: usize = 200;
    let cfg = ServeCfg {
        dir: dir.clone(),
        socket: dir.join("bench.sock"),
        state_dir: dir.join("mpqd"),
        workers: 1,
        max_idle: 1,
        max_jobs: N + 8, // every timed submit must be admitted
        fault_plan: None,
        hold: true,
    };
    let sock = cfg.socket.clone();
    let daemon = std::thread::spawn(move || daemon::run(cfg));
    let mut client = None;
    for _ in 0..1000 {
        match Client::connect(&sock) {
            Ok(c) => {
                client = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    let mut client = client.expect("mpqd never came up");
    let policy = JobPolicy::default();
    for _ in 0..8 {
        client.status().expect("warmup status");
    }
    let mut lat = Vec::with_capacity(N);
    for _ in 0..N {
        let t0 = std::time::Instant::now();
        client.submit(&spec.name, &policy).expect("submit");
        lat.push(t0.elapsed().as_secs_f64());
    }
    client.shutdown().expect("shutdown");
    daemon.join().expect("daemon thread").expect("daemon run");

    lat.sort_by(f64::total_cmp);
    for (tag, q) in [("p50", 0.50), ("p90", 0.90), ("p99", 0.99)] {
        let v = lat[((N as f64 * q) as usize).min(N - 1)];
        let r = BenchResult {
            name: format!("serve_sim/submit_roundtrip_{tag}"),
            min_s: v,
            mean_s: v,
            max_s: v,
            iters: N,
        };
        r.print();
        results.push(r);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The original artifacts-gated PJRT benches on `resnet_s`.
fn pjrt_benches(results: &mut Vec<BenchResult>) {
    let mut pipe = Pipeline::open(mpq::artifacts_dir(), "resnet_s").expect("open resnet_s");
    pipe.calibrate(256, 0).expect("calibrate");

    let entry = pipe.model.entry.clone();
    let cfg = QuantConfig::fixed(&entry, 8, 8);
    let cb = pipe.model.config_buffers(&cfg, &HashMap::new()).unwrap();

    // L3→PJRT: single quantized forward (the dominant cost of everything)
    {
        let set = pipe.calib_set().unwrap();
        let xb = &set.batches[0];
        results.push(bench_result("forward/one_batch_w8a8", 3, 20, || {
            pipe.model.forward(xb, &cb).map(|_| ())
        }));
    }

    // Phase-1 probe: one (g, c) streamed against the cached FP reference
    {
        let set = pipe.calib_set().unwrap();
        let ev = mpq::engine::Evaluator::new(&pipe.model, set);
        results.push(bench_result("phase1/sqnr_probe_256imgs", 1, 5, || {
            let pcfg = sensitivity::probe_config(
                &pipe.model.entry,
                1,
                mpq::groups::Candidate::new(8, 8),
            );
            ev.sqnr(&pcfg, &HashMap::new()).map(|_| ())
        }));
    }

    // Phase-1: the full sensitivity sweep through the engine (reference
    // cached after warmup — steady-state `probes × sweep` cost)
    {
        let lat = Lattice::practical();
        results.push(bench_result("phase1/full_sensitivity_sweep", 1, 3, || {
            pipe.sensitivity_sqnr(&lat).map(|_| ())
        }));
    }

    // config materialization (host-side row patching, should be ≪ forward)
    results.push(bench("config/qparam_tensors", 10, 200, || {
        let _ = pipe.model.qparam_tensors(&cfg).unwrap();
    }));
    results.push(bench("config/buffers_upload", 5, 50, || {
        let _ = pipe.model.config_buffers(&cfg, &HashMap::new()).unwrap();
    }));

    // quant substrate: MSE weight-scale search on the largest conv
    {
        let wq = entry
            .w_quantizers
            .iter()
            .max_by_key(|q| pipe.model.weights[q.param_idx].numel())
            .unwrap();
        let w = pipe.model.weights[wq.param_idx].clone();
        let ratios = quant::default_ratios();
        results.push(bench("quant/weight_scales_mse_largest", 2, 20, || {
            let _ = quant::weight_scales_mse(&w, wq.channels, wq.channel_axis, 8, &ratios)
                .unwrap();
        }));
    }

    // act-range grid accumulation (host side of calibration)
    {
        let mut ar = quant::ActRanges::new(1, vec![4, 6, 8, 16], quant::default_ratios());
        let mut rng = mpq::util::Rng::new(1);
        let data: Vec<f32> = (0..131072).map(|_| rng.f64() as f32 * 4.0 - 1.0).collect();
        let t = Tensor::from_f32(&[131072], data).unwrap();
        results.push(bench("quant/act_grid_accumulate_131k", 2, 20, || {
            ar.accumulate(std::slice::from_ref(&t), 1).unwrap();
        }));
    }

    // Phase-2 ledger walk (pure host arithmetic)
    {
        let lat = Lattice::practical();
        let sens = pipe.sensitivity_sqnr(&lat).unwrap();
        results.push(bench("phase2/flip_sequence", 10, 1000, || {
            let _ = pipe.flips(&lat, &sens);
        }));
    }

    // SQNR aggregation on host logits
    {
        let set = pipe.calib_set().unwrap();
        let fp = sensitivity::fp_logits(&pipe.model, set).unwrap();
        results.push(bench("metrics/sqnr_db_2048x10", 5, 200, || {
            let _ = sensitivity::sqnr_db(&fp, &fp).unwrap();
        }));
    }

    // Phase-2: binary accuracy-target search end-to-end (memoized finish)
    {
        let lat = Lattice::practical();
        let sens = pipe.sensitivity_sqnr(&lat).unwrap();
        let flips = pipe.flips(&lat, &sens);
        let fp = pipe.eval_fp32().unwrap();
        let target = fp - 0.02;
        results.push(bench_result("phase2/binary_search", 1, 5, || {
            pipe.search_accuracy_target(&lat, &flips, target, SearchScheme::Binary, None)
                .map(|_| ())
        }));
    }

    // Phase-1 sweep through the EvalPool at 1/2/4 workers (N private PJRT
    // clients + eval-set shards); memo cleared per iteration as above.
    {
        let lat = Lattice::practical();
        for workers in [1usize, 2, 4] {
            let mut pp =
                Pipeline::open(mpq::artifacts_dir(), "resnet_s").expect("open resnet_s");
            pp.enable_pool(workers).expect("spawn eval pool");
            pp.calibrate(256, 0).expect("calibrate");
            let name = format!("phase1_pool/full_sensitivity_sweep_w{workers}");
            results.push(bench_result(&name, 1, 3, || {
                pp.clear_eval_memo();
                pp.sensitivity_sqnr(&lat).map(|_| ())
            }));
        }
    }
}
