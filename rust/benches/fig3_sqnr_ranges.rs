//! `cargo bench --bench fig3_sqnr_ranges` — regenerates Fig 3: per-network SQNR ranges at W8A8
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("fig3_sqnr_ranges", "Fig 3: per-network SQNR ranges at W8A8") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::fig3(&opts).expect("fig3");
    tab.print();
    tab.save(mpq::report::results_dir(), "fig3").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
