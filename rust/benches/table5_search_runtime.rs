//! `cargo bench --bench table5_search_runtime` — regenerates Table 5: sequential vs binary vs hybrid search run-time
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("table5_search_runtime", "Table 5: sequential vs binary vs hybrid search run-time") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::table5(&opts).expect("table5");
    tab.print();
    tab.save(mpq::report::results_dir(), "table5").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
