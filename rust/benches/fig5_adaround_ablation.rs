//! `cargo bench --bench fig5_adaround_ablation` — regenerates Fig 5: AdaRound interweaving ablation
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("fig5_adaround_ablation", "Fig 5: AdaRound interweaving ablation") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::fig5(&opts).expect("fig5");
    tab.print();
    tab.save(mpq::report::results_dir(), "fig5").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
