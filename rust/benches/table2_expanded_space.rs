//! `cargo bench --bench table2_expanded_space` — regenerates Table 2: expanded low-bit search space
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("table2_expanded_space", "Table 2: expanded low-bit search space") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::table2(&opts).expect("table2");
    tab.print();
    tab.save(mpq::report::results_dir(), "table2").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
