//! `cargo bench --bench fig4_ood_calibration` — regenerates Fig 4: out-of-domain calibration data
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("fig4_ood_calibration", "Fig 4: out-of-domain calibration data") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let tab = experiments::fig4(&opts).expect("fig4");
    tab.print();
    tab.save(mpq::report::results_dir(), "fig4").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
