//! `cargo bench --bench fig2_metric_robustness` — regenerates Fig 2: calibration-robustness of acc/SQNR/FIT metrics
//! and times its dominant phase.  Uses the in-tree harness
//! (rust/src/bench); criterion is unavailable offline.

use mpq::experiments::{self, Opts};

fn main() {
    if !mpq::bench::preamble("fig2_metric_robustness", "Fig 2: calibration-robustness of acc/SQNR/FIT metrics") {
        return;
    }
    let opts = Opts::default();
    let t = mpq::util::Timer::start();
    
    let (a, b) = experiments::fig2(&opts).expect("fig2");
    a.print();
    b.print();
    a.save(mpq::report::results_dir(), "fig2_curves").unwrap();
    b.save(mpq::report::results_dir(), "fig2_ktau").unwrap();
    println!("total wall: {:.1}s", t.secs());
}
