"""MPQT interchange format roundtrips (python side; mirrored in rust)."""

import io

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import tensorio as tio


@settings(max_examples=50, deadline=None)
@given(
    shape=st.lists(st.integers(1, 8), min_size=0, max_size=4),
    use_int=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_roundtrip(shape, use_int, seed):
    rng = np.random.default_rng(seed)
    if use_int:
        a = rng.integers(-1000, 1000, size=shape).astype(np.int32)
    else:
        a = rng.standard_normal(shape).astype(np.float32)
    buf = io.BytesIO()
    tio.write_tensor(buf, a)
    buf.seek(0)
    b = tio.read_tensor(buf)
    assert b.dtype == a.dtype
    np.testing.assert_array_equal(a, b)


def test_multi_tensor_stream():
    buf = io.BytesIO()
    ts = [np.arange(6, dtype=np.float32).reshape(2, 3),
          np.arange(4, dtype=np.int32)]
    for t in ts:
        tio.write_tensor(buf, t)
    buf.seek(0)
    out = []
    while True:
        t = tio.read_tensor(buf)
        if t is None:
            break
        out.append(t)
    assert len(out) == 2
    np.testing.assert_array_equal(out[0], ts[0])
    np.testing.assert_array_equal(out[1], ts[1])


def test_rejects_float64():
    buf = io.BytesIO()
    try:
        tio.write_tensor(buf, np.zeros(3, np.float64))
        assert False, "should reject f64"
    except TypeError:
        pass
