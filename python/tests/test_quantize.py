"""QCtx spec invariants: quantizer registration, MAC accounting, quantizer
groups (§3.4) — the metadata contract the Rust coordinator depends on."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import models as M
from compile.quantize import QCtx

ZOO = ["resnet_s", "mobilenet_v3_s", "vit_s", "bert_s_mnli_s", "deeplab_s"]


@pytest.fixture(scope="module")
def specs():
    out = {}
    rng = np.random.default_rng(0)
    for name in ZOO:
        d = M.MODELS[name]
        p = d.init(rng)
        ctx = QCtx(collect=True)
        logits = d.apply(ctx, p, jnp.asarray(d.example(2)))
        out[name] = (ctx.spec(), logits, p)
    return out


def test_groups_partition_quantizers(specs):
    for name, (spec, _, _) in specs.items():
        a_seen = [0] * len(spec["act_quantizers"])
        w_seen = [0] * len(spec["w_quantizers"])
        for g in spec["groups"]:
            for a in g["act_q"]:
                a_seen[a] += 1
            for w in g["w_q"]:
                w_seen[w] += 1
        assert all(c == 1 for c in a_seen), name
        assert all(c == 1 for c in w_seen), name


def test_group_macs_sum_to_total(specs):
    for name, (spec, _, _) in specs.items():
        assert sum(g["macs"] for g in spec["groups"]) == spec["total_macs"], name
        assert sum(l["macs"] for l in spec["layers"]) == spec["total_macs"], name


def test_every_layer_input_act_in_its_group(specs):
    """§3.4: an op's weight quantizer and its input activation quantizers
    must share a group (they select one kernel)."""
    for name, (spec, _, _) in specs.items():
        for lay in spec["layers"]:
            g = next(g for g in spec["groups"] if lay["w_q"] in g["w_q"])
            for a in lay["in_acts"]:
                assert a in g["act_q"], f"{name}:{lay['name']}"


def test_conv_macs_formula():
    """stem conv of resnet_s: 16×16 out, 16 cout, 3 cin, 3×3 kernel."""
    d = M.MODELS["resnet_s"]
    p = d.init(np.random.default_rng(0))
    ctx = QCtx(collect=True)
    d.apply(ctx, p, jnp.asarray(d.example(2)))
    stem = next(l for l in ctx.layers if l["name"] == "stem")
    assert stem["macs"] == 16 * 16 * 16 * 3 * 3 * 3


def test_fp_and_collect_agree_on_output(specs):
    for name, (_, logits, p) in specs.items():
        d = M.MODELS[name]
        out2 = d.apply(QCtx(qparams=None), p, jnp.asarray(d.example(2)))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(out2), atol=1e-5)


def test_quantized_path_close_to_fp_at_16bit():
    d = M.MODELS["resnet_s"]
    p = d.init(np.random.default_rng(3))
    x = jnp.asarray(np.random.default_rng(1).normal(size=d.example(2).shape).astype(np.float32))
    fp = d.apply(QCtx(qparams=None), p, x)

    ctx = QCtx(collect=True)
    d.apply(ctx, p, x)
    A, W = len(ctx.act_q), len(ctx.w_q)
    cmax = max(q["channels"] for q in ctx.w_q)
    # 16-bit acts via generous symmetric ranges (offset at mid-grid so
    # negative activations aren't clipped), weights FP
    act = np.tile(np.array([1.5e-3, 32768, 0, 65535, 1], np.float32), (A, 1))
    wsc = np.ones((W, cmax), np.float32)
    wm = np.tile(np.array([-1, 1, 0], np.float32), (W, 1))
    q = d.apply(QCtx(qparams=(jnp.asarray(act), jnp.asarray(wsc), jnp.asarray(wm))), p, x)
    np.testing.assert_allclose(np.asarray(fp), np.asarray(q), atol=2e-2, rtol=1e-3)


def test_weightless_groups_have_zero_macs(specs):
    for name, (spec, _, _) in specs.items():
        for g in spec["groups"]:
            if not g["w_q"]:
                assert g["macs"] == 0, name
