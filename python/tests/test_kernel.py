"""L1 correctness: Pallas fake-quant kernels vs the pure-jnp oracle.

The CORE correctness signal for the compute layer — hypothesis sweeps
shapes, scales, offsets and bit-widths and asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant as fqk
from compile.kernels import ref

SHAPES = st.lists(st.integers(1, 9), min_size=1, max_size=4)


def rand(rng, shape, scale=3.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(
    shape=SHAPES,
    bits=st.sampled_from([4, 6, 8, 16]),
    scale=st.floats(1e-3, 0.5),
    seed=st.integers(0, 2**16),
)
def test_act_kernel_matches_ref(shape, bits, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand(rng, tuple(shape)))
    levels = float(2**bits - 1)
    off = float(rng.integers(0, levels))
    a = fqk.fake_quant_act(
        x, jnp.float32(scale), jnp.float32(off), jnp.float32(0),
        jnp.float32(levels), jnp.float32(1.0))
    b = ref.fake_quant_act_ref(x, scale, off, 0.0, levels, 1.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    cout=st.integers(1, 12),
    rest=st.lists(st.integers(1, 6), min_size=1, max_size=3),
    bits=st.sampled_from([4, 8]),
    axis_last=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_weight_kernel_matches_ref(cout, rest, bits, axis_last, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rest) + (cout,) if axis_last else (cout,) + tuple(rest)
    axis = len(shape) - 1 if axis_last else 0
    w = jnp.asarray(rand(rng, shape, 1.0))
    sc = jnp.asarray(np.abs(rng.standard_normal(cout)).astype(np.float32) * 0.1 + 1e-3)
    qmax = float(2 ** (bits - 1) - 1)
    a = fqk.fake_quant_weight(w, sc, -qmax, qmax, 1.0, channel_axis=axis)
    b = ref.fake_quant_weight_ref(w, sc, -qmax, qmax, 1.0, channel_axis=axis)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_enable_zero_is_identity():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rand(rng, (5, 7)))
    y = fqk.fake_quant_act(x, jnp.float32(0.05), jnp.float32(3.0),
                           jnp.float32(0), jnp.float32(255), jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_quantized_values_on_grid():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rand(rng, (64,)))
    s, o = 0.07, 11.0
    y = np.asarray(fqk.fake_quant_act(
        x, jnp.float32(s), jnp.float32(o), jnp.float32(0),
        jnp.float32(255), jnp.float32(1.0)))
    q = y / s + o
    np.testing.assert_allclose(q, np.round(q), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**16),
)
def test_matmul_fq_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand(rng, (m, k), 1.0))
    w = jnp.asarray(rand(rng, (k, n), 1.0))
    a = fqk.matmul_fq(x, w, 0.1, 0.0, -128.0, 127.0, 1.0)
    b = ref.matmul_fq_ref(x, w, 0.1, 0.0, -128.0, 127.0, 1.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_idempotence():
    """fq(fq(x)) == fq(x) — quantization is a projection."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rand(rng, (33,)))
    args = (jnp.float32(0.03), jnp.float32(7.0), jnp.float32(0),
            jnp.float32(255), jnp.float32(1.0))
    y1 = fqk.fake_quant_act(x, *args)
    y2 = fqk.fake_quant_act(y1, *args)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
