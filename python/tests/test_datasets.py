"""Dataset generators: determinism, label semantics, split disjointness."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datasets as ds


def test_synthnet_deterministic():
    a = ds.synthnet("train", 64)
    b = ds.synthnet("train", 64)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_splits_differ():
    a, _ = ds.synthnet("train", 64)
    b, _ = ds.synthnet("val", 64)
    assert not np.allclose(a, b)


def test_synthnet_shapes_and_labels():
    x, y = ds.synthnet("train", 100)
    assert x.shape == (100, 3, ds.IMG, ds.IMG)
    assert x.dtype == np.float32
    assert y.min() >= 0 and y.max() < ds.N_CLASSES


def test_synthood_statistically_different():
    a, _ = ds.synthnet("calib", 256)
    b, _ = ds.synthood("calib", 256)
    # different generators → clearly different second moments per channel
    assert abs(a.std() - b.std()) > 0.05 or abs(a.mean() - b.mean()) > 0.05


def test_synthseg_mask_semantics():
    x, y = ds.synthseg("train", 50)
    assert y.shape == (50, ds.IMG, ds.IMG)
    assert set(np.unique(y)).issubset({0, 1, 2})
    # every image has some background
    assert all((y[i] == 0).any() for i in range(50))


@settings(max_examples=10, deadline=None)
@given(task=st.sampled_from(list(ds.GLUE_TASKS)), seed=st.integers(0, 100))
def test_synthglue_label_ranges(task, seed):
    toks, ys = ds.synthglue(task, "train", 64, seed)
    assert toks.shape == (64, ds.SEQ_LEN)
    assert toks.min() >= 0 and toks.max() < ds.VOCAB
    n_out, _ = ds.GLUE_TASKS[task]
    if task == "stsb_s":
        assert ys.min() >= 0.0 and ys.max() <= 1.0
    else:
        assert set(np.unique(ys)).issubset(set(float(i) for i in range(n_out)))


def test_rte_entailment_rule():
    """positives: hypothesis tokens ⊆ premise tokens."""
    toks, ys = ds.synthglue("rte_s", "train", 200, 0)
    for t, y in zip(toks, ys):
        seq = [int(v) for v in t if v != ds.PAD]
        # [CLS] a... [SEP] b... [SEP]
        sep1 = seq.index(ds.SEP)
        a = set(seq[1:sep1])
        b = set(seq[sep1 + 1:-1])
        assert (float(b.issubset(a)) == y) or y == 1.0 and b.issubset(a) or y == 0.0


def test_sst2_rule():
    toks, ys = ds.synthglue("sst2_s", "train", 200, 1)
    for t, y in zip(toks, ys):
        seq = [int(v) for v in t if v not in (ds.PAD, ds.CLS, ds.SEP)]
        pos = sum(v in ds.POS_TOKENS for v in seq)
        neg = sum(v in ds.NEG_TOKENS for v in seq)
        assert float(pos >= neg) == y


def test_glue_classes_reasonably_balanced():
    _, ys = ds.synthglue("mnli_s", "train", 600, 0)
    counts = np.bincount(ys.astype(int), minlength=3)
    assert counts.min() > 100
