"""Model zoo: output shapes, trainability smoke, outlier inducement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets as ds
from compile import models as M
from compile import train as T
from compile.quantize import QCtx


@pytest.mark.parametrize("name", list(M.MODELS))
def test_output_shapes(name):
    d = M.MODELS[name]
    p = d.init(np.random.default_rng(0))
    out = d.apply(QCtx(qparams=None), p, jnp.asarray(d.example(2)))
    if d.task == "seg":
        assert out.shape == (2, ds.SEG_CLASSES, ds.IMG, ds.IMG)
    elif d.task == "classify10":
        assert out.shape == (2, ds.N_CLASSES)
    else:
        n_out, _ = ds.GLUE_TASKS[d.task.split(":")[1]]
        assert out.shape == (2, n_out)


def test_short_training_reduces_loss():
    d = M.MODELS["resnet_s"]
    d2 = M.ModelDef(d.name, d.task, d.init, d.apply, d.example,
                    dict(steps=30, lr=2e-3))
    params, metric = T.train_model(d2, verbose=False)
    assert metric > 2.0 / ds.N_CLASSES  # clearly better than chance


def test_outlier_models_have_wide_activations():
    """The baked-in channel gains must produce visibly wider activation
    ranges at the .amp site than at its producer (the Fig. 3 premise)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(ds.synthnet("train", 8)[0])
    d = M.MODELS["mobilenet_v3_s"]
    p = d.init(rng)
    ctx = QCtx(qparams=None)
    ctx.capture_acts = True
    d.apply(ctx, p, x)
    names = [q["name"] for q in ctx.act_q]
    ranges = {n: float(jnp.max(jnp.abs(a))) for n, a in zip(names, ctx.captured_acts)}
    amp = next(n for n in names if ".amp." in n)
    dw = next(n for n in names if n.startswith("b2.dw"))
    assert ranges[amp] > 4.0 * ranges[dw], (ranges[amp], ranges[dw])


def test_metric_helpers():
    logits = np.array([[2.0, 1.0], [0.0, 3.0]], np.float32)
    y = np.array([0.0, 1.0], np.float32)
    assert T.metric("classify10", logits, y) == 1.0
    assert T.metric("glue:mrpc_s", logits, y) == 1.0
    # pearson on stsb-style
    l2 = np.array([[0.1], [0.5], [0.9]], np.float32)
    y2 = np.array([0.0, 0.5, 1.0], np.float32)
    assert T.metric("glue:stsb_s", l2, y2) > 0.99
