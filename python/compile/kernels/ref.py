"""Pure-jnp oracle for the fake-quantization kernels.

This module is the ground truth the Pallas kernels in ``fake_quant.py`` are
validated against (see ``python/tests/test_kernel.py``).  It implements the
paper's uniform affine quantizer (Eq. 1-2):

    W_int = clip(round(W / s + o), qmin, qmax)
    q(W)  = (W_int - o) * s

plus the ``enable`` blend used throughout this repo so that a single lowered
HLO executable can represent *any* bit-width configuration (including FP32,
``enable = 0``):

    y = x + enable * (q(x) - x)

Weights use symmetric per-channel quantization (offset = 0, scale is a vector
over the output-channel axis); activations use asymmetric per-tensor
quantization (scalar scale + offset).
"""

from __future__ import annotations

import jax.numpy as jnp


def fake_quant_ref(x, scale, offset, qmin, qmax, enable):
    """Reference fake-quant. ``scale``/``offset`` broadcast against ``x``.

    All of ``qmin``/``qmax``/``enable`` are scalars (python or 0-d arrays).
    ``enable`` is 0.0 or 1.0; fractional values interpolate (used nowhere in
    the algorithm but harmless, and it keeps the op differentiable-ish).
    """
    s = jnp.maximum(scale, 1e-12)  # guard padded/zero channels
    q = jnp.clip(jnp.round(x / s + offset), qmin, qmax)
    y = (q - offset) * s
    return x + enable * (y - x)


def fake_quant_act_ref(x, scale, offset, qmin, qmax, enable):
    """Per-tensor asymmetric activation fake-quant (scalar scale/offset)."""
    return fake_quant_ref(x, scale, offset, qmin, qmax, enable)


def fake_quant_weight_ref(w, scale, qmin, qmax, enable, channel_axis=0):
    """Per-channel symmetric weight fake-quant.

    ``scale`` has shape ``(C,)`` where ``C = w.shape[channel_axis]``.
    """
    shp = [1] * w.ndim
    shp[channel_axis] = -1
    s = scale.reshape(shp)
    return fake_quant_ref(w, s, 0.0, qmin, qmax, enable)


def matmul_fq_ref(x, w, scale, offset, qmin, qmax, enable):
    """Fused ``fake_quant(x @ w)`` oracle for the fused Pallas kernel."""
    return fake_quant_ref(jnp.matmul(x, w), scale, offset, qmin, qmax, enable)
