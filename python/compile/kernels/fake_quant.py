"""L1 Pallas kernels: fused fake-quantization.

The fake-quant op is the hot-spot of the paper's simulation substrate: it is
executed at *every* quantizer on *every* Phase-1 probe and Phase-2
configuration evaluation, i.e. tens of thousands of times per mixed-precision
search.  We implement it as a Pallas kernel so that the whole quantized
forward pass lowers into one HLO module (see ``python/compile/aot.py``).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the op is elementwise, so
it targets the VPU.  Tensors are flattened to a 2-D ``(rows, LANES)`` layout
with ``LANES = 128`` (the VPU lane count) and tiled into ``(BLOCK_ROWS, 128)``
VMEM blocks; per-channel scales ride along as a ``(BLOCK_ROWS, 1)`` column so
the broadcast happens inside the block.  The fused ``matmul + fake_quant``
variant tiles ``(128, 128)`` output blocks for the MXU and quantizes the
accumulator in VMEM before write-back — the analogue of the paper's W4A8
integer kernels where the producer quantizes its output activation.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime can load (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128          # VPU lane width
SUBLANES = 8         # f32 sublane count; row blocks are multiples of this
MAX_BLOCK_ROWS = 64  # 64×128 f32 = 32 KiB per block, comfortably in VMEM

_INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fq_kernel(x_ref, s_ref, m_ref, o_ref):
    """One (BLOCK_ROWS, LANES) block of fake-quant.

    ``s_ref`` is ``(BLOCK_ROWS, 1)`` (per-channel, broadcast over lanes) or
    ``(1, 1)`` (per-tensor).  ``m_ref`` is the (1, 4) meta row
    ``(offset, qmin, qmax, enable)`` — scalars shared by every block.
    """
    x = x_ref[...]
    s = jnp.maximum(s_ref[...], 1e-12)
    off = m_ref[0, 0]
    qmin = m_ref[0, 1]
    qmax = m_ref[0, 2]
    en = m_ref[0, 3]
    q = jnp.clip(jnp.round(x / s + off), qmin, qmax)
    y = (q - off) * s
    o_ref[...] = x + en * (y - x)


def _fq_2d(x2, s2, meta):
    """Run the block kernel over a padded ``(R, C)`` array.

    ``R`` is a multiple of SUBLANES, ``C`` a multiple of LANES; ``s2`` is
    ``(R, 1)`` or ``(1, 1)``.

    Grid choice: on real TPU hardware this would tile
    ``(MAX_BLOCK_ROWS, LANES)`` VMEM blocks; under ``interpret=True`` on the
    CPU PJRT plugin every grid step lowers to an XLA while-loop iteration,
    which both bloats compile time (dozens of fq sites per model) and slows
    execution.  Since the whole padded tensor fits host memory, we run a
    single-block grid here and document the TPU BlockSpec in DESIGN.md
    §Hardware-Adaptation.
    """
    rows, cols = x2.shape
    per_channel = s2.shape[0] != 1
    s_spec = (
        pl.BlockSpec((rows, 1), lambda: (0, 0))
        if per_channel
        else pl.BlockSpec((1, 1), lambda: (0, 0))
    )
    return pl.pallas_call(
        _fq_kernel,
        in_specs=[
            pl.BlockSpec((rows, cols), lambda: (0, 0)),
            s_spec,
            pl.BlockSpec((1, 4), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x2.dtype),
        interpret=_INTERPRET,
    )(x2, s2, meta)


def fake_quant_act(x, scale, offset, qmin, qmax, enable):
    """Per-tensor asymmetric fake-quant of an activation tensor.

    ``scale``/``offset``/``qmin``/``qmax``/``enable`` are 0-d arrays (traced —
    they are runtime inputs of the lowered executable).
    """
    n = x.size
    cols = LANES
    rows = _ceil_to(max(1, (n + cols - 1) // cols), SUBLANES)
    x2 = jnp.zeros((rows * cols,), x.dtype).at[:n].set(x.reshape(-1))
    x2 = x2.reshape(rows, cols)
    meta = jnp.stack([offset, qmin, qmax, enable]).reshape(1, 4).astype(x.dtype)
    s2 = jnp.reshape(scale, (1, 1)).astype(x.dtype)
    out = _fq_2d(x2, s2, meta)
    return out.reshape(-1)[:n].reshape(x.shape)


def fake_quant_weight(w, scale, qmin, qmax, enable, channel_axis=0):
    """Per-channel symmetric fake-quant of a weight tensor.

    ``scale`` is ``(C,)`` over ``channel_axis``; offset is fixed at 0
    (symmetric).  The tensor is viewed as ``(C, rest)`` so each block row
    carries its own scale.
    """
    wm = jnp.moveaxis(w, channel_axis, 0)
    c, rest = wm.shape[0], int(wm.size // wm.shape[0])
    cols = _ceil_to(max(rest, 1), LANES)
    rows = _ceil_to(c, SUBLANES)
    x2 = jnp.zeros((rows, cols), w.dtype).at[:c, :rest].set(wm.reshape(c, rest))
    s2 = jnp.zeros((rows, 1), w.dtype).at[:c, 0].set(scale.astype(w.dtype))
    zero = jnp.zeros((), w.dtype)
    meta = jnp.stack(
        [zero, jnp.asarray(qmin, w.dtype), jnp.asarray(qmax, w.dtype), jnp.asarray(enable, w.dtype)]
    ).reshape(1, 4)
    out = _fq_2d(x2, s2, meta)[:c, :rest].reshape(wm.shape)
    return jnp.moveaxis(out, 0, channel_axis)


def _matmul_fq_kernel(x_ref, w_ref, m_ref, o_ref, *, k_steps):
    """Fused ``fake_quant(x @ w)`` block kernel.

    Grid is ``(M/bm, N/bn, K/bk)``; the K axis is the innermost (sequential)
    dimension, accumulating into the output block, which stays resident in
    VMEM because its index map is constant along K.  On the last K step the
    accumulator is fake-quantized in place — quantization happens VMEM-side,
    exactly where the paper's integer kernel would requantize its int32
    accumulator.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...]

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        acc = o_ref[...]
        s = jnp.maximum(m_ref[0, 0], 1e-12)
        off = m_ref[0, 1]
        qmin = m_ref[0, 2]
        qmax = m_ref[0, 3]
        en = m_ref[0, 4]
        q = jnp.clip(jnp.round(acc / s + off), qmin, qmax)
        y = (q - off) * s
        o_ref[...] = acc + en * (y - acc)


def matmul_fq(x, w, scale, offset, qmin, qmax, enable, block=(128, 128, 128)):
    """Fused ``fake_quant(x @ w)`` with MXU-shaped (128,128) output tiles.

    Shapes are padded to block multiples; the result is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = block
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.zeros((mp, kp), x.dtype).at[:m, :k].set(x)
    wp = jnp.zeros((kp, np_), w.dtype).at[:k, :n].set(w)
    meta = jnp.stack(
        [jnp.asarray(v, x.dtype) for v in (scale, offset, qmin, qmax, enable)]
    ).reshape(1, 5)
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_fq_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, 5), lambda i, j, l: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=_INTERPRET,
    )(xp, wp, meta)
    return out[:m, :n]
