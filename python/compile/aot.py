"""AOT lowering: JAX → HLO text → artifacts/ for the Rust runtime.

``python -m compile.aot --out-dir ../artifacts`` is the *only* python
entrypoint in the system; after it runs, the Rust binary is self-contained.
Per model it emits:

- ``<m>.fwd.hlo.txt``   quantized forward.  Inputs, in order:
      x, param_0..param_{P-1}, act_qp[A,5], w_scales[W,Cmax], w_qmeta[W,3]
  Output: 1-tuple of logits.  ``enable=0`` rows bypass quantizers exactly,
  so the same executable serves FP32 eval, Phase-1 probes and any mixed
  configuration (DESIGN.md §2).
- ``<m>.weights.bin``   trained parameters, MPQT tensors in params order.
- ``<m>.taps.hlo.txt``  FP forward returning every weighted op's input
  (AdaRound calibration captures), CNN models only.
- ``<m>.ar.<layer>.hlo.txt``  per-layer AdaRound loss+grad step
  (x, w, b, v, scale, meta[qmin,qmax,beta,lam]) → (loss, dL/dV).
- ``<m>.fit.hlo.txt``   FIT-metric probe (Fig. 2): FP forward with
  per-quantizer zero perturbations; returns (loss, wgrad2[W], agrad2[A],
  aerr2[A]).

plus shared dataset binaries and a global ``manifest.json``.

HLO **text** (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as ds
from . import models as M
from . import tensorio as tio
from . import train as T
from .quantize import QCtx

# models that get taps + AdaRound artifacts (Table 4 / Fig. 5 scope: CNNs)
ADAROUND_MODELS = {
    "resnet_s", "resnet_m", "mobilenet_v2_s", "mobilenet_v3_s",
    "effnet_lite_s", "effnet_b0_s", "deeplab_s",
}
# models that get the FIT probe (Fig. 2 runs on mobilenet_v2_s; resnet_s is
# used by the unit tests because it is the cheapest)
FIT_MODELS = {"mobilenet_v2_s", "resnet_s"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is LOAD-BEARING: the default printer elides
    # big constant payloads as "constant({...})", which xla_extension 0.5.1's
    # text parser silently reads back as ZEROS — any graph with a baked-in
    # constant array (outlier gains, positional tables) then miscomputes.
    return comp.as_hlo_text(print_large_constants=True)


def _collect_spec(mdef, params):
    ctx = QCtx(collect=True)
    out = mdef.apply(ctx, params, jnp.asarray(mdef.example(M.BATCH)))
    return ctx.spec(), [int(s) for s in out.shape]


def _qparam_shapes(spec):
    a = len(spec["act_quantizers"])
    w = len(spec["w_quantizers"])
    cmax = max((q["channels"] for q in spec["w_quantizers"]), default=1)
    return a, w, cmax


def lower_forward(mdef, params, spec, out_path):
    names = list(params.keys())
    a, w, cmax = _qparam_shapes(spec)

    def fwd(x, *rest):
        plist = rest[:len(names)]
        act_qp, w_scales, w_qmeta = rest[len(names):]
        ctx = QCtx(qparams=(act_qp, w_scales, w_qmeta))
        return (mdef.apply(ctx, dict(zip(names, plist)), x),)

    ex = mdef.example(M.BATCH)
    args = [jax.ShapeDtypeStruct(ex.shape, ex.dtype)]
    args += [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype) for k in names]
    args += [
        jax.ShapeDtypeStruct((a, 5), np.float32),
        jax.ShapeDtypeStruct((w, cmax), np.float32),
        jax.ShapeDtypeStruct((w, 3), np.float32),
    ]
    text = to_hlo_text(jax.jit(fwd).lower(*args))
    with open(out_path, "w") as f:
        f.write(text)


def lower_taps(mdef, params, out_path):
    """FP forward returning each weighted op's input tensor (+ logits)."""
    names = list(params.keys())

    def taps(x, *plist):
        ctx = QCtx(qparams=None, capture_taps=True)
        out = mdef.apply(ctx, dict(zip(names, plist)), x)
        return tuple(t for _, t in ctx.taps) + (out,)

    ex = mdef.example(M.BATCH)
    args = [jax.ShapeDtypeStruct(ex.shape, ex.dtype)]
    args += [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype) for k in names]
    text = to_hlo_text(jax.jit(taps).lower(*args))
    with open(out_path, "w") as f:
        f.write(text)


# MSE range-estimation grid (mirrored by rust/src/quant): for every
# activation quantizer we evaluate the local quantization MSE of clipping
# the observed [min,max] range by each ratio, at each candidate bit-width.
STATS_BITS = [4, 6, 8, 16]
STATS_RATIOS = [round(0.30 + 0.05 * i, 2) for i in range(15)]  # 0.30..1.00


def lower_stats(mdef, params, spec, out_path):
    """Activation-capture probe for MSE range estimation.

    FP forward returning every activation quantizer's input tensor; the MSE
    grid over (bits × clip-ratio) — the paper's 'MSE based criteria' — is
    computed host-side in `rust/src/quant` from these captures.

    (History: computing the grid *inside* the graph either exploded
    xla_extension 0.5.1's CPU compile time (per-cell unrolled form) or
    miscompiled into constant-folded rows (broadcast-vectorized form on
    model-sized graphs).  Capturing raw activations keeps the artifact a
    plain data path and moves the arithmetic into testable Rust.)
    """
    names = list(params.keys())

    def stats(x, *plist):
        ctx = QCtx(qparams=None)
        ctx.capture_acts = True
        mdef.apply(ctx, dict(zip(names, plist)), x)
        return tuple(ctx.captured_acts)

    ex = mdef.example(M.BATCH)
    args = [jax.ShapeDtypeStruct(ex.shape, ex.dtype)]
    args += [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype) for k in names]
    text = to_hlo_text(jax.jit(stats).lower(*args))
    with open(out_path, "w") as f:
        f.write(text)


def _rect_sigmoid(v):
    return jnp.clip(jax.nn.sigmoid(v) * 1.2 - 0.1, 0.0, 1.0)


def lower_adaround_step(layer, out_path):
    """Per-layer AdaRound step (Nagel et al. 2020; paper §3.5 integration).

    loss = ||op(x, W) − op(x, Ŵ(V))||² + λ Σ(1 − |2h(V)−1|^β),
    Ŵ(V) = s · clip(floor(W/s) + h(V), qmin, qmax).
    Returns (loss, dL/dV); the Adam loop lives in rust/src/adaround.
    """
    kind = layer["kind"]
    x_shape = tuple(layer["in_shape"])
    w_shape = tuple(layer["w_shape"])
    c_axis = 0 if kind == "conv" else 1
    channels = w_shape[c_axis]

    def op(x, w, b):
        if kind == "conv":
            y = jax.lax.conv_general_dilated(
                x, w, window_strides=(layer["stride"],) * 2,
                padding=layer["padding"],
                feature_group_count=layer["groups"],
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )
            return y + b.reshape(1, -1, 1, 1)
        return x @ w + b

    def step(x, w, b, v, scale, meta):
        qmin, qmax, beta, lam = meta[0], meta[1], meta[2], meta[3]
        shp = [1] * len(w_shape)
        shp[c_axis] = -1
        s = jnp.maximum(scale, 1e-12).reshape(shp)

        def loss_fn(vv):
            h = _rect_sigmoid(vv)
            wq = s * jnp.clip(jnp.floor(w / s) + h, qmin, qmax)
            mse = jnp.mean((op(x, w, b) - op(x, wq, b)) ** 2)
            reg = jnp.mean(1.0 - jnp.abs(2.0 * h - 1.0) ** beta)
            return mse + lam * reg

        loss, g = jax.value_and_grad(loss_fn)(v)
        return loss, g

    f32 = np.float32
    args = [
        jax.ShapeDtypeStruct(x_shape, f32),
        jax.ShapeDtypeStruct(w_shape, f32),
        jax.ShapeDtypeStruct((w_shape[1] if kind == "dense" else w_shape[0],), f32),
        jax.ShapeDtypeStruct(w_shape, f32),
        jax.ShapeDtypeStruct((channels,), f32),
        jax.ShapeDtypeStruct((4,), f32),
    ]
    text = to_hlo_text(jax.jit(step).lower(*args))
    with open(out_path, "w") as f:
        f.write(text)


def lower_fit(mdef, params, spec, out_path):
    """FIT probe (Zandonati et al.): FP forward + per-quantizer Fisher terms.

    Inputs: x, y, params..., perts..., act_qp.  Outputs (loss, wgrad2[W],
    agrad2[A], aerr2[A]) where *grad2 are mean squared loss-gradients
    (Fisher diagonal approximation) and aerr2 is each activation's local
    quantization MSE under the given act_qp rows.
    """
    names = list(params.keys())
    loss_fn = T._loss_fn(mdef.task)
    a, w, cmax = _qparam_shapes(spec)
    ex = mdef.example(M.BATCH)

    # record each quantizer's activation shape with a tracing subclass
    shapes = []

    class _ShapeCtx(QCtx):
        def quant_act(self, x, name, src_of=None):
            shapes.append(tuple(int(d) for d in x.shape))
            return super().quant_act(x, name, src_of)

    _ShapeCtx(qparams=None).__class__  # silence linters
    sctx = _ShapeCtx(qparams=None)
    mdef.apply(sctx, params, jnp.asarray(ex))
    act_shapes = shapes

    wq_param = [q["weight"] for q in spec["w_quantizers"]]

    def fit(x, y, *rest):
        plist = list(rest[:len(names)])
        perts = list(rest[len(names):len(names) + len(act_shapes)])
        act_qp = rest[len(names) + len(act_shapes)]

        def loss_of(pl, pe):
            ctx = QCtx(qparams=(act_qp, None, None), perts=pe, fit_mode=True)
            logits = mdef.apply(ctx, dict(zip(names, pl)), x)
            return loss_fn(logits, y), ctx.fit_errs

        (loss, errs), grads = jax.value_and_grad(
            loss_of, argnums=(0, 1), has_aux=True)(plist, perts)
        gp, ga = grads
        pidx = {n: i for i, n in enumerate(names)}
        wgrad2 = jnp.stack([jnp.mean(gp[pidx[p]] ** 2) for p in wq_param])
        agrad2 = jnp.stack([jnp.mean(g ** 2) for g in ga])
        aerr2 = jnp.stack(errs)
        return loss, wgrad2, agrad2, aerr2

    f32 = np.float32
    if mdef.task == "seg":
        y_spec = jax.ShapeDtypeStruct((M.BATCH, ds.IMG, ds.IMG), np.int32)
    else:
        y_spec = jax.ShapeDtypeStruct((M.BATCH,), f32)
    args = [jax.ShapeDtypeStruct(ex.shape, ex.dtype), y_spec]
    args += [jax.ShapeDtypeStruct(params[k].shape, params[k].dtype) for k in names]
    args += [jax.ShapeDtypeStruct(s, f32) for s in act_shapes]
    args += [jax.ShapeDtypeStruct((a, 5), f32)]
    text = to_hlo_text(jax.jit(fit).lower(*args))
    with open(out_path, "w") as f:
        f.write(text)
    return [list(s) for s in act_shapes]


# ---------------------------------------------------------------------------
# datasets
# ---------------------------------------------------------------------------

CALIB_N = 1024
VAL_N = T.VAL_N


def dump_datasets(out_dir):
    """Shared dataset binaries; returns {task: data-manifest fragment}."""
    frag = {}

    def dump(prefix, xs, ys):
        tio.write_tensors(os.path.join(out_dir, prefix + ".bin"), [xs])
        tio.write_tensors(
            os.path.join(out_dir, prefix + ".labels.bin"),
            [ys if ys.dtype == np.int32 else ys.astype(np.float32)],
        )

    cx, cy = ds.synthnet("calib", CALIB_N)
    vx, vy = ds.synthnet("val", VAL_N)
    ox, _ = ds.synthood("calib", CALIB_N)
    dump("synthnet_calib", cx, cy.astype(np.float32))
    dump("synthnet_val", vx, vy.astype(np.float32))
    tio.write_tensors(os.path.join(out_dir, "synthood_calib.bin"), [ox])
    frag["classify10"] = {
        "calib": "synthnet_calib.bin", "calib_labels": "synthnet_calib.labels.bin",
        "val": "synthnet_val.bin", "val_labels": "synthnet_val.labels.bin",
        "ood_calib": "synthood_calib.bin",
    }

    cx, cy = ds.synthseg("calib", CALIB_N)
    vx, vy = ds.synthseg("val", VAL_N)
    dump("synthseg_calib", cx, cy)
    dump("synthseg_val", vx, vy)
    frag["seg"] = {
        "calib": "synthseg_calib.bin", "calib_labels": "synthseg_calib.labels.bin",
        "val": "synthseg_val.bin", "val_labels": "synthseg_val.labels.bin",
        "ood_calib": "synthood_calib.bin",
    }

    for t in ds.GLUE_TASKS:
        cx, cy = ds.synthglue(t, "calib", CALIB_N)
        vx, vy = ds.synthglue(t, "val", VAL_N)
        dump(f"glue_{t}_calib", cx, cy)
        dump(f"glue_{t}_val", vx, vy)
        frag[f"glue:{t}"] = {
            "calib": f"glue_{t}_calib.bin",
            "calib_labels": f"glue_{t}_calib.labels.bin",
            "val": f"glue_{t}_val.bin", "val_labels": f"glue_{t}_val.labels.bin",
            "ood_calib": None,
        }
    return frag


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def build_model(name, out_dir, data_frag, fast=False, reuse_weights=False):
    mdef = M.MODELS[name]
    if fast:
        mdef.train_cfg = dict(mdef.train_cfg, steps=40)
    t0 = time.time()
    params, fp_metric = None, None
    if reuse_weights:
        # re-lower without retraining: load frozen weights + recorded metric
        wpath = os.path.join(out_dir, f"{name}.weights.bin")
        mpath = os.path.join(out_dir, "manifest.json")
        if os.path.exists(wpath):
            ws = tio.read_tensors(wpath)
            old = None
            if os.path.exists(mpath):
                with open(mpath) as f:
                    old = json.load(f)["models"].get(name)
            if old and len(old["params"]) == len(ws):
                pnames = [p["name"] for p in old["params"]]
                fp_metric = old["fp32_val_metric"]
            else:
                # manifest entry lost: param names come from a fresh init
                # (deterministic order); metric is recomputed, not retrained
                pnames = list(mdef.init(np.random.default_rng(17)).keys())
                fp_metric = None
            if len(pnames) == len(ws):
                params = dict(zip(pnames, ws))
                if fp_metric is None:
                    fp_metric = T.eval_model(mdef, params)
                print(f"[aot] {name}: reusing trained weights", flush=True)
    if params is None:
        params, fp_metric = T.train_model(mdef)
    spec, out_shape = _collect_spec(mdef, params)
    a, w, cmax = _qparam_shapes(spec)
    names = list(params.keys())

    tio.write_tensors(os.path.join(out_dir, f"{name}.weights.bin"),
                      [params[k] for k in names])
    lower_forward(mdef, params, spec,
                  os.path.join(out_dir, f"{name}.fwd.hlo.txt"))
    lower_stats(mdef, params, spec, os.path.join(out_dir, f"{name}.stats.hlo.txt"))

    is_tok = mdef.task.startswith("glue:")
    entry = {
        "task": mdef.task,
        "batch": M.BATCH,
        "input": {"shape": list(mdef.example(M.BATCH).shape),
                  "dtype": "i32" if is_tok else "f32"},
        "forward": f"{name}.fwd.hlo.txt",
        "stats": f"{name}.stats.hlo.txt",
        "stats_bits": STATS_BITS,
        "stats_ratios": STATS_RATIOS,
        "weights_file": f"{name}.weights.bin",
        "params": [{"name": k, "shape": list(params[k].shape)} for k in names],
        "out_shape": out_shape,
        "act_quantizers": spec["act_quantizers"],
        "w_quantizers": spec["w_quantizers"],
        "layers": spec["layers"],
        "groups": spec["groups"],
        "total_macs": spec["total_macs"],
        "cmax": cmax,
        "fp32_val_metric": fp_metric,
        "data": data_frag[mdef.task],
        "taps": None,
        "adaround": [],
        "fit": None,
        "fit_act_shapes": None,
    }

    if name in ADAROUND_MODELS:
        lower_taps(mdef, params, os.path.join(out_dir, f"{name}.taps.hlo.txt"))
        entry["taps"] = f"{name}.taps.hlo.txt"
        pshape = {p["name"]: p["shape"] for p in entry["params"]}
        for i, lay in enumerate(spec["layers"]):
            layer = dict(lay)
            layer["w_shape"] = pshape[lay["name"] + ".w"]
            exe = f"{name}.ar.{lay['name']}.hlo.txt"
            lower_adaround_step(layer, os.path.join(out_dir, exe))
            entry["adaround"].append({
                "layer": lay["name"], "exe": exe, "tap_index": i,
                "param": lay["name"] + ".w", "bias": lay["name"] + ".b",
                "kind": lay["kind"],
                "channels": layer["w_shape"][0 if lay["kind"] == "conv" else 1],
            })

    if name in FIT_MODELS:
        shapes = lower_fit(mdef, params, spec,
                           os.path.join(out_dir, f"{name}.fit.hlo.txt"))
        entry["fit"] = f"{name}.fit.hlo.txt"
        entry["fit_act_shapes"] = shapes

    print(f"[aot] {name}: A={a} W={w} groups={len(spec['groups'])} "
          f"macs={spec['total_macs']} fp32={fp_metric:.4f} "
          f"({time.time()-t0:.1f}s)", flush=True)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="all", help="comma list or 'all'")
    ap.add_argument("--fast", action="store_true",
                    help="40 training steps (CI smoke)")
    ap.add_argument("--reuse-weights", action="store_true",
                    help="skip training when weights exist (re-lower only)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = list(M.MODELS) if args.models == "all" else args.models.split(",")
    data_frag = dump_datasets(args.out_dir)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"version": 1, "models": {}}
    if os.path.exists(manifest_path):
        # merge into the existing manifest so partial rebuilds (and the
        # --reuse-weights path, which reads it) keep the other models
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name in names:
        manifest["models"][name] = build_model(
            name, args.out_dir, data_frag, fast=args.fast,
            reuse_weights=args.reuse_weights)
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {manifest_path}")


if __name__ == "__main__":
    main()
