"""Binary tensor interchange between the python build path and Rust.

Format ("MPQT"): little-endian throughout.

    u32 magic = 0x4D505154 ("MPQT")
    u8  dtype   (0 = f32, 1 = i32)
    u8  ndim
    u16 reserved = 0
    u32 dims[ndim]
    payload (dtype, C-order)

Multiple tensors may be concatenated in one file; readers consume
sequentially.  The Rust counterpart lives in ``rust/src/tensor/io.rs``.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = 0x4D505154
_DT = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_tensor(f, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    if arr.dtype not in _DT:
        raise TypeError(f"unsupported dtype {arr.dtype}")
    f.write(struct.pack("<IBBH", MAGIC, _DT[arr.dtype], arr.ndim, 0))
    f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
    f.write(arr.tobytes())


def write_tensors(path, arrays) -> None:
    with open(path, "wb") as f:
        for a in arrays:
            write_tensor(f, a)


def read_tensor(f):
    hdr = f.read(8)
    if not hdr:
        return None
    magic, dt, ndim, _ = struct.unpack("<IBBH", hdr)
    assert magic == MAGIC, f"bad magic {magic:#x}"
    dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
    dtype = np.float32 if dt == 0 else np.int32
    n = int(np.prod(dims)) if ndim else 1
    data = np.frombuffer(f.read(n * 4), dtype=dtype)
    return data.reshape(dims)


def read_tensors(path):
    out = []
    with open(path, "rb") as f:
        while True:
            t = read_tensor(f)
            if t is None:
                return out
            out.append(t)
