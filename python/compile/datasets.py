"""Synthetic datasets standing in for the paper's benchmarks.

The paper evaluates on ImageNet-1K, Pascal VOC and GLUE, none of which are
available in this environment.  Per the substitution rule (DESIGN.md §3) we
build procedural equivalents that exercise the same code paths and preserve
the property the paper's experiments depend on: *per-layer quantization
sensitivity structure*, which is a function of architecture and activation
statistics, not of dataset scale.

- ``synthnet``  — ImageNet stand-in: 16×16×3 images, 10 classes.  Each class
  is a distinct Gabor-like oriented texture + palette; instances vary in
  phase, position jitter and additive noise.
- ``synthood``  — MS-COCO stand-in (Fig. 4 out-of-domain calibration): a
  *disjoint* generator (checkerboards / stripes, different palette) so the
  marginal pixel statistics differ from synthnet.
- ``synthseg``  — Pascal VOC stand-in: 16×16 images with paste-in shapes and
  per-pixel labels {background, square, disc}; metric is mIoU.
- ``synthglue`` — GLUE stand-in: five sequence tasks over a 48-token
  vocabulary matching Table 3's task-type mix (RTE/MRPC/MNLI-style pair
  classification, SST-2-style single-sequence classification, STS-B-style
  pair regression).

Everything is deterministic in (split, seed) so build-time training, Rust
calibration subsets and the ground-truth sensitivity lists all see
reproducible data.
"""

from __future__ import annotations

import numpy as np

IMG = 16  # image side
N_CLASSES = 10
VOCAB = 48
SEQ_LEN = 24
SEG_CLASSES = 3

# token-id conventions for synthglue
PAD, CLS, SEP = 0, 1, 2
POS_TOKENS = set(range(3, 13))   # "positive sentiment" words
NEG_TOKENS = set(range(13, 23))  # "negative sentiment" words
_CONTENT_LO, _CONTENT_HI = 3, VOCAB  # content tokens


def _rng(split: str, seed: int) -> np.random.Generator:
    return np.random.default_rng(abs(hash((split, seed))) % (2**63))


# --------------------------------------------------------------------------
# synthnet — 10-class oriented-texture images
# --------------------------------------------------------------------------

def synthnet(split: str, n: int, seed: int = 0):
    """Return ``(x[n,3,IMG,IMG] f32, y[n] i32)``."""
    rng = _rng("synthnet:" + split, seed)
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float32) / IMG
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    imgs = np.empty((n, 3, IMG, IMG), np.float32)
    for i, c in enumerate(labels):
        theta = np.pi * c / N_CLASSES
        freq = 2.0 + (c % 5)
        phase = rng.uniform(0, 2 * np.pi)
        u = np.cos(theta) * xx + np.sin(theta) * yy
        tex = np.sin(2 * np.pi * freq * u + phase)
        # class-dependent palette, instance-dependent brightness
        base = np.array(
            [np.cos(0.7 * c), np.cos(0.7 * c + 2.1), np.cos(0.7 * c + 4.2)],
            np.float32,
        )
        bright = rng.uniform(0.6, 1.4)
        img = bright * (0.5 * base[:, None, None] * tex[None] + 0.5 * tex[None])
        img += rng.normal(0, 0.55, size=(3, IMG, IMG))
        imgs[i] = img
    return imgs.astype(np.float32), labels


def synthood(split: str, n: int, seed: int = 0):
    """Out-of-domain images (Fig. 4): checkerboard/stripe generator."""
    rng = _rng("synthood:" + split, seed)
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    imgs = np.empty((n, 3, IMG, IMG), np.float32)
    for i in range(n):
        p = int(rng.integers(2, 6))
        kind = rng.integers(0, 3)
        if kind == 0:
            pat = ((xx // p + yy // p) % 2).astype(np.float32)
        elif kind == 1:
            pat = ((xx // p) % 2).astype(np.float32)
        else:
            pat = ((yy // p) % 2).astype(np.float32)
        pal = rng.uniform(-1.5, 1.5, size=3).astype(np.float32)
        img = pal[:, None, None] * (2 * pat[None] - 1)
        img += rng.normal(0, 0.15, size=(3, IMG, IMG))
        imgs[i] = img
    labels = rng.integers(0, N_CLASSES, size=n).astype(np.int32)  # unused
    return imgs.astype(np.float32), labels


# --------------------------------------------------------------------------
# synthseg — 3-class segmentation
# --------------------------------------------------------------------------

def synthseg(split: str, n: int, seed: int = 0):
    """Return ``(x[n,3,IMG,IMG] f32, y[n,IMG,IMG] i32)`` with classes
    0=background, 1=square, 2=disc."""
    rng = _rng("synthseg:" + split, seed)
    imgs = np.empty((n, 3, IMG, IMG), np.float32)
    masks = np.zeros((n, IMG, IMG), np.int32)
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    for i in range(n):
        img = rng.normal(0, 0.3, size=(3, IMG, IMG)).astype(np.float32)
        mask = np.zeros((IMG, IMG), np.int32)
        for _ in range(int(rng.integers(1, 3))):
            kind = int(rng.integers(1, SEG_CLASSES))
            cx, cy = rng.integers(3, IMG - 3, size=2)
            r = int(rng.integers(2, 5))
            if kind == 1:
                sel = (np.abs(xx - cx) <= r) & (np.abs(yy - cy) <= r)
            else:
                sel = (xx - cx) ** 2 + (yy - cy) ** 2 <= r * r
            mask[sel] = kind
            col = rng.uniform(0.5, 1.5, size=3).astype(np.float32)
            sign = 1.0 if kind == 1 else -1.0
            for ch in range(3):
                img[ch][sel] += sign * col[ch]
        imgs[i], masks[i] = img, mask
    return imgs.astype(np.float32), masks


# --------------------------------------------------------------------------
# synthglue — five sequence tasks (Table 3)
# --------------------------------------------------------------------------

GLUE_TASKS = {
    # name: (n_outputs, metric)
    "rte_s": (2, "acc"),
    "mrpc_s": (2, "f1"),
    "sst2_s": (2, "acc"),
    "stsb_s": (1, "pearson"),
    "mnli_s": (3, "acc"),
}


def _rand_seq(rng, lo, hi, length):
    return rng.integers(lo, hi, size=length)


def _pack_pair(a, b):
    """[CLS] a [SEP] b [SEP] padded to SEQ_LEN."""
    toks = np.full(SEQ_LEN, PAD, np.int32)
    seq = [CLS, *a, SEP, *b, SEP]
    toks[: len(seq)] = seq[:SEQ_LEN]
    return toks


def _pack_single(a):
    toks = np.full(SEQ_LEN, PAD, np.int32)
    seq = [CLS, *a, SEP]
    toks[: len(seq)] = seq[:SEQ_LEN]
    return toks


def synthglue(task: str, split: str, n: int, seed: int = 0):
    """Return ``(tokens[n,SEQ_LEN] i32, y[n] f32)``.

    Labels are float32 throughout (class index for classification tasks,
    score in [0,1] for stsb_s) so Rust handles one label dtype.
    """
    rng = _rng(f"glue:{task}:{split}", seed)
    toks = np.empty((n, SEQ_LEN), np.int32)
    ys = np.empty((n,), np.float32)
    for i in range(n):
        if task == "rte_s":
            # entailment: does hypothesis's token multiset ⊆ premise's?
            a = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 9)
            if rng.random() < 0.5:
                b = rng.choice(a, size=4, replace=False)
                y = 1.0
            else:
                b = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 4)
                y = float(set(b).issubset(set(a.tolist())))
            toks[i], ys[i] = _pack_pair(a, b), y
        elif task == "mrpc_s":
            a = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 8)
            if rng.random() < 0.5:
                b = rng.permutation(a)
                y = 1.0
            else:
                b = a.copy()
                k = int(rng.integers(3, 6))
                idx = rng.choice(8, size=k, replace=False)
                b[idx] = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, k)
                b = rng.permutation(b)
                y = float(sorted(b.tolist()) == sorted(a.tolist()))
            toks[i], ys[i] = _pack_pair(a, b), y
        elif task == "sst2_s":
            a = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 14)
            pos = sum(t in POS_TOKENS for t in a.tolist())
            neg = sum(t in NEG_TOKENS for t in a.tolist())
            toks[i], ys[i] = _pack_single(a), float(pos >= neg)
        elif task == "stsb_s":
            a = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 8)
            k = int(rng.integers(0, 9))
            b = a.copy()
            if k:
                idx = rng.choice(8, size=k, replace=False)
                b[idx] = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, k)
            sa, sb = set(a.tolist()), set(b.tolist())
            y = len(sa & sb) / max(1, len(sa | sb))  # Jaccard ∈ [0,1]
            toks[i], ys[i] = _pack_pair(a, b), y
        elif task == "mnli_s":
            a = _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 9)
            r = rng.random()
            if r < 1 / 3:  # entail: subset
                b = rng.choice(a, size=4, replace=False)
                y = 0.0
            elif r < 2 / 3:  # contradict: fully disjoint
                pool = np.array(
                    [t for t in range(_CONTENT_LO, _CONTENT_HI) if t not in set(a.tolist())]
                )
                b = rng.choice(pool, size=4, replace=False)
                y = 1.0
            else:  # neutral: partial overlap
                b = np.concatenate(
                    [rng.choice(a, size=2, replace=False),
                     _rand_seq(rng, _CONTENT_LO, _CONTENT_HI, 2)]
                )
                sa = set(a.tolist())
                inter = len(sa & set(b.tolist()))
                y = 0.0 if inter == len(set(b.tolist())) else (1.0 if inter == 0 else 2.0)
            toks[i], ys[i] = _pack_pair(a, b), y
        else:
            raise ValueError(f"unknown glue task {task}")
    return toks, ys
