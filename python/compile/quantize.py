"""Quantization graph context (L2).

``QCtx`` is the instrumentation layer between the model zoo and the L1
fake-quant kernels.  Model code is written once against ``QCtx`` ops
(``conv``/``dense``/``add``/...) and serves three purposes:

1. **Training** (``qparams=None``): ops run un-quantized; ``train.py`` uses
   this path to pretrain the zoo at build time.
2. **Lowering** (``qparams=(act_qp, w_scales, w_qmeta)`` as traced arrays):
   every quantizer reads its runtime parameters from the packed arrays, so a
   *single* lowered HLO executable evaluates any bit-width configuration.
   Row layout (must match ``rust/src/manifest``):

   - ``act_qp   : f32[A, 5]`` rows ``(scale, offset, qmin, qmax, enable)``
   - ``w_scales : f32[W, Cmax]`` per-channel scales, zero-padded
   - ``w_qmeta  : f32[W, 3]`` rows ``(qmin, qmax, enable)``

3. **Spec collection** (``collect=True`` with concrete inputs): records the
   quantizer list, per-layer MAC counts (Eq. 5 BOPs substrate) and the
   quantizer groups (§3.4) that the Rust coordinator consumes via
   ``manifest.json``.

Quantizer-group semantics (§3.4): an integer kernel on device is selected by
(weight bits, *input* activation bits) of an op.  We therefore union, for
every weighted op, its weight quantizer with the activation quantizer(s)
producing its input.  Activation quantizers that feed no weighted op (e.g.
final logits) form weightless groups with zero BOPs gain and are pinned to
the baseline by the search.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import fake_quant as fqk
from .kernels import ref as fqr

# Pallas kernels are the default; MPQ_NO_PALLAS=1 switches to the jnp oracle
# (used by tests to diff the two lowerings).
USE_PALLAS = os.environ.get("MPQ_NO_PALLAS", "0") != "1"


class QT:
    """A tensor tagged with the activation quantizer that produced it."""

    __slots__ = ("a", "src")

    def __init__(self, a, src=None):
        self.a = a
        self.src = src  # act quantizer id or None (e.g. token ids)

    @property
    def shape(self):
        return self.a.shape


class _UnionFind:
    def __init__(self):
        self.parent = {}

    def find(self, x):
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a, b):
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


class QCtx:
    """See module docstring.  One instance per trace/collect run."""

    def __init__(self, qparams=None, collect=False, perts=None,
                 fit_mode=False, capture_taps=False):
        self.qparams = qparams  # None | (act_qp, w_scales, w_qmeta)
        self.collect = collect
        self.act_q = []      # [{name, numel}]
        self.w_q = []        # [{name, channels, weight, channel_axis}]
        self.weights = []    # [{name, shape}] in traversal order
        self._weight_idx = {}
        self.layers = []     # [{name, macs, w_q, in_acts}]
        self._uf = _UnionFind()
        # FIT metric support: forward runs FP, but each quantizer's *local*
        # quantization MSE (given its act_qp row) is collected, and a zero
        # perturbation input is added after each quantizer so grad-wrt-pert
        # yields dL/d(activation) for the Fisher term.
        self.perts = perts          # list of zero arrays (traced) or None
        self.fit_mode = fit_mode
        self.fit_errs = []          # traced scalars, one per act quantizer
        # AdaRound support: capture each weighted op's input tensor.
        self.capture_taps = capture_taps
        self.taps = []              # [(layer_name, traced array)]
        # Range-calibration support: capture every act quantizer's input.
        self.capture_acts = False
        self.captured_acts = []     # traced arrays, one per act quantizer

    # -- quantizer registration -------------------------------------------

    def _new_act_q(self, name, x):
        qid = len(self.act_q)
        if self.collect:
            self.act_q.append({"name": name, "numel": int(np.prod(x.shape[1:]))})
        else:
            self.act_q.append({"name": name})
        return qid

    def _new_w_q(self, name, w, channel_axis):
        qid = len(self.w_q)
        self.w_q.append(
            {
                "name": name,
                "channels": int(w.shape[channel_axis]),
                "weight": name,
                "channel_axis": channel_axis,
            }
        )
        return qid

    def _reg_weight(self, name, w):
        if name in self._weight_idx:
            raise ValueError(f"duplicate weight {name}")
        self._weight_idx[name] = len(self.weights)
        self.weights.append({"name": name, "shape": [int(s) for s in w.shape]})

    # -- fake-quant application -------------------------------------------

    def _fq_act(self, x, qid):
        if self.qparams is None:
            return x
        act_qp, _, _ = self.qparams
        r = act_qp[qid]
        fn = fqk.fake_quant_act if USE_PALLAS else fqr.fake_quant_act_ref
        return fn(x, r[0], r[1], r[2], r[3], r[4])

    def _fq_w(self, w, wid, channels, channel_axis):
        if self.qparams is None:
            return w
        _, w_scales, w_qmeta = self.qparams
        if w_scales is None:  # FIT mode: weights stay FP
            return w
        sc = w_scales[wid, :channels]
        m = w_qmeta[wid]
        fn = fqk.fake_quant_weight if USE_PALLAS else fqr.fake_quant_weight_ref
        return fn(w, sc, m[0], m[1], m[2], channel_axis=channel_axis)

    def quant_act(self, x, name, src_of=None):
        """Insert an activation quantizer; returns a tagged QT."""
        qid = self._new_act_q(name, x)
        if self.capture_acts:
            self.captured_acts.append(x)
        if self.fit_mode:
            act_qp, _, _ = self.qparams
            r = act_qp[qid]
            xq = fqr.fake_quant_act_ref(x, r[0], r[1], r[2], r[3], 1.0)
            self.fit_errs.append(jnp.mean((x - xq) ** 2))
            y = x  # FP forward for the Fisher gradients
        else:
            y = self._fq_act(x, qid)
        if self.perts is not None:
            y = y + self.perts[qid]
        return QT(y, qid)

    # -- graph bookkeeping --------------------------------------------------

    def _record_op(self, name, macs, w_qid, in_srcs, op_cfg=None):
        rec = {
            "name": name,
            "macs": int(macs),
            "w_q": w_qid,
            "in_acts": [s for s in in_srcs if s is not None],
        }
        if op_cfg:
            rec.update(op_cfg)
        self.layers.append(rec)
        for s in in_srcs:
            if s is not None:
                self._uf.union(("w", w_qid), ("a", s))

    def _record_eltwise(self, srcs):
        """§3.4: inputs of a shared (weightless) op — add, mul, concat —
        must be quantized to the same precision, so their quantizers are
        unioned into one group."""
        srcs = [s for s in srcs if s is not None]
        for a, b in zip(srcs, srcs[1:]):
            self._uf.union(("a", a), ("a", b))

    # -- ops -----------------------------------------------------------------

    def input(self, x, name="input"):
        return self.quant_act(x, name)

    def tokens(self, t):
        """Integer token ids: no quantizer."""
        return QT(t, None)

    def conv(self, qt, w, b, name, stride=1, padding="SAME", groups=1, act=None):
        """2-D conv, NCHW/OIHW.  Weight per-channel quant over axis 0."""
        if self.capture_taps:
            self.taps.append((name, qt.a))
        self._reg_weight(name + ".w", w)
        wid = self._new_w_q(name + ".w", w, 0)
        wq = self._fq_w(w, wid, int(w.shape[0]), 0)
        s = (stride, stride) if isinstance(stride, int) else stride
        y = jax.lax.conv_general_dilated(
            qt.a,
            wq,
            window_strides=s,
            padding=padding,
            feature_group_count=groups,
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        y = y + b.reshape(1, -1, 1, 1)
        cout, cin_g, kh, kw = w.shape
        ho, wo = int(y.shape[2]), int(y.shape[3])
        macs = ho * wo * cout * cin_g * kh * kw
        self._record_op(
            name, macs, wid, [qt.src],
            op_cfg={
                "kind": "conv",
                "stride": s[0],
                "padding": padding,
                "groups": groups,
                "in_shape": [int(d) for d in qt.a.shape],
            } if self.collect else None,
        )
        if act is not None:
            y = act(y)
        return self.quant_act(y, name + ".out")

    def dense(self, qt, w, b, name, act=None):
        """Dense over the last axis.  Weight per-channel quant over out axis 1."""
        if self.capture_taps:
            self.taps.append((name, qt.a))
        self._reg_weight(name + ".w", w)
        wid = self._new_w_q(name + ".w", w, 1)
        wq = self._fq_w(w, wid, int(w.shape[1]), 1)
        y = qt.a @ wq + b
        tokens = int(np.prod(qt.a.shape[1:-1])) if qt.a.ndim > 2 else 1
        macs = tokens * int(w.shape[0]) * int(w.shape[1])
        self._record_op(
            name, macs, wid, [qt.src],
            op_cfg={
                "kind": "dense",
                "in_shape": [int(d) for d in qt.a.shape],
            } if self.collect else None,
        )
        if act is not None:
            y = act(y)
        return self.quant_act(y, name + ".out")

    def add(self, a, b, name):
        """Residual add; the sum gets a fresh quantizer and the two inputs
        are constrained to one group (§3.4)."""
        self._record_eltwise([a.src, b.src])
        return self.quant_act(a.a + b.a, name + ".out")

    def mul(self, a, b, name):
        """Elementwise/broadcast mul (SE gating); fresh quantizer, grouped
        inputs (§3.4)."""
        self._record_eltwise([a.src, b.src])
        return self.quant_act(a.a * b.a, name + ".out")

    def concat(self, parts, name, axis=1):
        """Channel concat; grouped inputs (§3.4), fresh output quantizer."""
        self._record_eltwise([t.src for t in parts])
        return self.quant_act(
            jnp.concatenate([t.a for t in parts], axis=axis), name + ".out"
        )

    def const_gain(self, qt, gain, name):
        """Fixed per-channel gain baked into the graph (outlier inducement —
        see DESIGN.md §3).  The scaled tensor gets a fresh quantizer, whose
        wide range is exactly the pathology the paper observes in
        MobileNetV3 / EfficientNet-b0 / ViT / BERT."""
        g = jnp.asarray(gain, jnp.float32).reshape(1, -1, *([1] * (qt.a.ndim - 2)))
        return self.quant_act(qt.a * g, name + ".out")

    def layer_norm(self, qt, g, b, name, eps=1e-5):
        """LayerNorm over last axis (FP compute, quantized output)."""
        x = qt.a
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        y = (x - mu) / jnp.sqrt(var + eps) * g + b
        return self.quant_act(y, name + ".out")

    def global_pool(self, qt, name):
        """Global average pool NCHW→NC; fresh quantizer (range changes)."""
        return self.quant_act(qt.a.mean((2, 3)), name + ".out")

    def avg_pool2(self, qt, name):
        """2×2 average pool, stride 2; reuses the input quantizer tag (an
        average never widens the range, matching deployed graphs where the
        pool runs in the producer's precision)."""
        x = qt.a
        y = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
        ) / 4.0
        return QT(y, qt.src)

    def softmax_attention(self, q, k, v, name, scale):
        """FP attention core (QKᵀ softmax V); output gets a quantizer.
        The two act×act matmuls carry no weight quantizer; their MACs are
        negligible at this scale (documented in DESIGN.md)."""
        att = jax.nn.softmax((q.a @ jnp.swapaxes(k.a, -1, -2)) * scale, axis=-1)
        return self.quant_act(att @ v.a, name + ".att.out")

    def upsample2d(self, qt, factor, name):
        """Nearest-neighbour upsample; reuses producer quantizer."""
        x = qt.a
        x = jnp.repeat(jnp.repeat(x, factor, axis=2), factor, axis=3)
        return QT(x, qt.src)

    # -- spec export -----------------------------------------------------------

    def spec(self):
        """Manifest fragment: quantizers, layers, groups (collect mode)."""
        assert self.collect
        # group ids from union-find roots; stable ordering by first member
        roots = {}
        groups = []

        def gid_of(node):
            r = self._uf.find(node)
            if r not in roots:
                roots[r] = len(groups)
                groups.append({"w_q": [], "act_q": [], "macs": 0})
            return roots[r]

        for i in range(len(self.w_q)):
            groups[gid_of(("w", i))]["w_q"].append(i)
        for i in range(len(self.act_q)):
            groups[gid_of(("a", i))]["act_q"].append(i)
        for lay in self.layers:
            groups[gid_of(("w", lay["w_q"]))]["macs"] += lay["macs"]
        return {
            "act_quantizers": self.act_q,
            "w_quantizers": [
                {k: v for k, v in d.items()} for d in self.w_q
            ],
            "weights": self.weights,
            "layers": self.layers,
            "groups": groups,
            "total_macs": int(sum(l["macs"] for l in self.layers)),
        }
