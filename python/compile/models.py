"""The model zoo (L2).

Miniaturized but architecturally faithful versions of the paper's nine
evaluation networks (Table 1), written against :class:`compile.quantize.QCtx`
so one definition serves training, spec collection and AOT lowering.

Architecture ↔ paper mapping (DESIGN.md §3):

========================  =====================================================
paper network             here — what is preserved
========================  =====================================================
ResNet18                  ``resnet_s``: stem + basic residual blocks
ResNet50                  ``resnet_m``: bottleneck (1-3-1) residual blocks
MobileNetV2               ``mobilenet_v2_s``: inverted residuals, depthwise,
                          ReLU6, linear bottleneck
MobileNetV3               ``mobilenet_v3_s``: + hard-swish, SE blocks, and a
                          baked-in per-channel outlier gain (the activation
                          pathology the paper observes)
EfficientNet-lite         ``effnet_lite_s``: MBConv w/o SE, ReLU6
EfficientNet-b0           ``effnet_b0_s``: MBConv + SE + SiLU + strong outlier
                          gain (the paper's catastrophic W8A8 case)
ViT                       ``vit_s``: patch-embed transformer, LayerNorm/GELU,
                          outlier gain in one MLP
BERT                      ``bert_s``: token+pos embeddings, transformer
                          encoder, per-GLUE-task heads
DeepLabV3-MobileNetV3     ``deeplab_s``: mobilenet_v3 trunk + ASPP-style head,
                          per-pixel 3-class logits
========================  =====================================================

All CNNs take NCHW ``f32[B,3,16,16]``; transformers take ``i32[B,24]`` token
ids.  Every model returns raw logits; losses/metrics live in ``train.py``
(build time) and ``rust/src/metrics`` (run time).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as ds
from .quantize import QCtx, QT

# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def relu(x):
    return jax.nn.relu(x)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def hswish(x):
    return x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0


def silu(x):
    return jax.nn.silu(x)


def gelu(x):
    return jax.nn.gelu(x)


# ---------------------------------------------------------------------------
# parameter init helpers
# ---------------------------------------------------------------------------

def _conv_p(p, name, cin, cout, k, rng, groups=1):
    fan_in = (cin // groups) * k * k
    p[name + ".w"] = (rng.normal(size=(cout, cin // groups, k, k)) *
                      np.sqrt(2.0 / fan_in)).astype(np.float32)
    p[name + ".b"] = np.zeros(cout, np.float32)


def _dense_p(p, name, din, dout, rng):
    p[name + ".w"] = (rng.normal(size=(din, dout)) *
                      np.sqrt(2.0 / din)).astype(np.float32)
    p[name + ".b"] = np.zeros(dout, np.float32)


def _ln_p(p, name, d):
    p[name + ".g"] = np.ones(d, np.float32)
    p[name + ".b"] = np.zeros(d, np.float32)


def _outlier_gain(c, hot=(1, 7), mag=14.0):
    """Fixed per-channel gain with a few large entries.  Baked into the graph
    to reproduce the wide-activation-range pathology of MobileNetV3 /
    EfficientNet-b0 / ViT (paper Fig. 3) on miniature networks."""
    g = np.ones(c, np.float32)
    for h in hot:
        if h < c:
            g[h] = mag
    return g


# ---------------------------------------------------------------------------
# CNN building blocks
# ---------------------------------------------------------------------------

def _basic_block(ctx, p, x, name, cin, cout, stride):
    y = ctx.conv(x, p[f"{name}.c1.w"], p[f"{name}.c1.b"], f"{name}.c1",
                 stride=stride, act=relu)
    y = ctx.conv(y, p[f"{name}.c2.w"], p[f"{name}.c2.b"], f"{name}.c2", act=relu)
    if stride != 1 or cin != cout:
        x = ctx.conv(x, p[f"{name}.sk.w"], p[f"{name}.sk.b"], f"{name}.sk",
                     stride=stride)
    return ctx.add(x, y, name)


def _basic_block_p(p, name, cin, cout, stride, rng):
    _conv_p(p, f"{name}.c1", cin, cout, 3, rng)
    _conv_p(p, f"{name}.c2", cout, cout, 3, rng)
    if stride != 1 or cin != cout:
        _conv_p(p, f"{name}.sk", cin, cout, 1, rng)


def _bottleneck(ctx, p, x, name, cin, cmid, cout, stride):
    y = ctx.conv(x, p[f"{name}.c1.w"], p[f"{name}.c1.b"], f"{name}.c1", act=relu)
    y = ctx.conv(y, p[f"{name}.c2.w"], p[f"{name}.c2.b"], f"{name}.c2",
                 stride=stride, act=relu)
    y = ctx.conv(y, p[f"{name}.c3.w"], p[f"{name}.c3.b"], f"{name}.c3")
    if stride != 1 or cin != cout:
        x = ctx.conv(x, p[f"{name}.sk.w"], p[f"{name}.sk.b"], f"{name}.sk",
                     stride=stride)
    return ctx.add(x, y, name)


def _bottleneck_p(p, name, cin, cmid, cout, stride, rng):
    _conv_p(p, f"{name}.c1", cin, cmid, 1, rng)
    _conv_p(p, f"{name}.c2", cmid, cmid, 3, rng)
    _conv_p(p, f"{name}.c3", cmid, cout, 1, rng)
    if stride != 1 or cin != cout:
        _conv_p(p, f"{name}.sk", cin, cout, 1, rng)


def _se(ctx, p, x, name, c, r=4):
    s = ctx.global_pool(x, f"{name}.se.gap")
    s = ctx.dense(s, p[f"{name}.se.d1.w"], p[f"{name}.se.d1.b"],
                  f"{name}.se.d1", act=relu)
    s = ctx.dense(s, p[f"{name}.se.d2.w"], p[f"{name}.se.d2.b"],
                  f"{name}.se.d2", act=jax.nn.sigmoid)
    gate = QT(s.a[:, :, None, None], s.src)
    return ctx.mul(x, gate, f"{name}.se")


def _se_p(p, name, c, rng, r=4):
    _dense_p(p, f"{name}.se.d1", c, max(1, c // r), rng)
    _dense_p(p, f"{name}.se.d2", max(1, c // r), c, rng)


def _irb(ctx, p, x, name, cin, cout, stride, exp, act, se=False, gain=None):
    """Inverted residual / MBConv block."""
    cmid = cin * exp
    y = ctx.conv(x, p[f"{name}.ex.w"], p[f"{name}.ex.b"], f"{name}.ex", act=act)
    y = ctx.conv(y, p[f"{name}.dw.w"], p[f"{name}.dw.b"], f"{name}.dw",
                 stride=stride, groups=cmid, act=act)
    if gain is not None:
        y = ctx.const_gain(y, gain, f"{name}.amp")
    if se:
        y = _se(ctx, p, y, name, cmid)
    y = ctx.conv(y, p[f"{name}.pj.w"], p[f"{name}.pj.b"], f"{name}.pj")
    if stride == 1 and cin == cout:
        y = ctx.add(x, y, name)
    return y


def _irb_p(p, name, cin, cout, stride, exp, rng, se=False):
    cmid = cin * exp
    _conv_p(p, f"{name}.ex", cin, cmid, 1, rng)
    _conv_p(p, f"{name}.dw", cmid, cmid, 3, rng, groups=cmid)
    if se:
        _se_p(p, name, cmid, rng)
    _conv_p(p, f"{name}.pj", cmid, cout, 1, rng)


# ---------------------------------------------------------------------------
# CNN classifiers
# ---------------------------------------------------------------------------

def resnet_s_init(rng):
    p = {}
    _conv_p(p, "stem", 3, 16, 3, rng)
    _basic_block_p(p, "b1", 16, 16, 1, rng)
    _basic_block_p(p, "b2", 16, 16, 1, rng)
    _basic_block_p(p, "b3", 16, 32, 2, rng)
    _basic_block_p(p, "b4", 32, 32, 1, rng)
    _dense_p(p, "fc", 32, ds.N_CLASSES, rng)
    return p


def resnet_s_apply(ctx: QCtx, p, x):
    h = ctx.input(x)
    h = ctx.conv(h, p["stem.w"], p["stem.b"], "stem", act=relu)
    h = _basic_block(ctx, p, h, "b1", 16, 16, 1)
    h = _basic_block(ctx, p, h, "b2", 16, 16, 1)
    h = _basic_block(ctx, p, h, "b3", 16, 32, 2)
    h = _basic_block(ctx, p, h, "b4", 32, 32, 1)
    h = ctx.global_pool(h, "gap")
    h = ctx.dense(h, p["fc.w"], p["fc.b"], "fc")
    return h.a


def resnet_m_init(rng):
    p = {}
    _conv_p(p, "stem", 3, 16, 3, rng)
    _bottleneck_p(p, "b1", 16, 8, 16, 1, rng)
    _bottleneck_p(p, "b2", 16, 8, 16, 1, rng)
    _bottleneck_p(p, "b3", 16, 16, 32, 2, rng)
    _bottleneck_p(p, "b4", 32, 16, 32, 1, rng)
    _bottleneck_p(p, "b5", 32, 16, 32, 1, rng)
    _dense_p(p, "fc", 32, ds.N_CLASSES, rng)
    return p


def resnet_m_apply(ctx, p, x):
    h = ctx.input(x)
    h = ctx.conv(h, p["stem.w"], p["stem.b"], "stem", act=relu)
    h = _bottleneck(ctx, p, h, "b1", 16, 8, 16, 1)
    h = _bottleneck(ctx, p, h, "b2", 16, 8, 16, 1)
    h = _bottleneck(ctx, p, h, "b3", 16, 16, 32, 2)
    h = _bottleneck(ctx, p, h, "b4", 32, 16, 32, 1)
    h = _bottleneck(ctx, p, h, "b5", 32, 16, 32, 1)
    h = ctx.global_pool(h, "gap")
    h = ctx.dense(h, p["fc.w"], p["fc.b"], "fc")
    return h.a


def mobilenet_v2_s_init(rng):
    p = {}
    _conv_p(p, "stem", 3, 12, 3, rng)
    _irb_p(p, "b1", 12, 12, 1, 3, rng)
    _irb_p(p, "b2", 12, 18, 2, 3, rng)
    _irb_p(p, "b3", 18, 18, 1, 3, rng)
    _irb_p(p, "b4", 18, 24, 2, 3, rng)
    _dense_p(p, "fc", 24, ds.N_CLASSES, rng)
    return p


def mobilenet_v2_s_apply(ctx, p, x):
    h = ctx.input(x)
    h = ctx.conv(h, p["stem.w"], p["stem.b"], "stem", act=relu6)
    h = _irb(ctx, p, h, "b1", 12, 12, 1, 3, relu6)
    h = _irb(ctx, p, h, "b2", 12, 18, 2, 3, relu6)
    h = _irb(ctx, p, h, "b3", 18, 18, 1, 3, relu6)
    h = _irb(ctx, p, h, "b4", 18, 24, 2, 3, relu6)
    h = ctx.global_pool(h, "gap")
    h = ctx.dense(h, p["fc.w"], p["fc.b"], "fc")
    return h.a


def _mnv3_trunk(ctx, p, x):
    """Shared trunk for mobilenet_v3_s and deeplab_s; returns 4×4 features."""
    h = ctx.input(x)
    h = ctx.conv(h, p["stem.w"], p["stem.b"], "stem", act=hswish)
    h = _irb(ctx, p, h, "b1", 12, 12, 1, 3, hswish, se=True)
    h = _irb(ctx, p, h, "b2", 12, 18, 2, 3, hswish,
             gain=_outlier_gain(36, hot=(1, 7), mag=12.0))
    h = _irb(ctx, p, h, "b3", 18, 18, 1, 3, hswish, se=True)
    h = _irb(ctx, p, h, "b4", 18, 24, 2, 3, hswish)
    return h


def _mnv3_trunk_p(rng):
    p = {}
    _conv_p(p, "stem", 3, 12, 3, rng)
    _irb_p(p, "b1", 12, 12, 1, 3, rng, se=True)
    _irb_p(p, "b2", 12, 18, 2, 3, rng)
    _irb_p(p, "b3", 18, 18, 1, 3, rng, se=True)
    _irb_p(p, "b4", 18, 24, 2, 3, rng)
    return p


def mobilenet_v3_s_init(rng):
    p = _mnv3_trunk_p(rng)
    _dense_p(p, "fc", 24, ds.N_CLASSES, rng)
    return p


def mobilenet_v3_s_apply(ctx, p, x):
    h = _mnv3_trunk(ctx, p, x)
    h = ctx.global_pool(h, "gap")
    h = ctx.dense(h, p["fc.w"], p["fc.b"], "fc")
    return h.a


def effnet_lite_s_init(rng):
    p = {}
    _conv_p(p, "stem", 3, 12, 3, rng)
    _irb_p(p, "b1", 12, 12, 1, 3, rng)
    _irb_p(p, "b2", 12, 18, 2, 4, rng)
    _irb_p(p, "b3", 18, 24, 2, 4, rng)
    _conv_p(p, "head", 24, 48, 1, rng)
    _dense_p(p, "fc", 48, ds.N_CLASSES, rng)
    return p


def effnet_lite_s_apply(ctx, p, x):
    h = ctx.input(x)
    h = ctx.conv(h, p["stem.w"], p["stem.b"], "stem", act=relu6)
    h = _irb(ctx, p, h, "b1", 12, 12, 1, 3, relu6)
    h = _irb(ctx, p, h, "b2", 12, 18, 2, 4, relu6)
    h = _irb(ctx, p, h, "b3", 18, 24, 2, 4, relu6)
    h = ctx.conv(h, p["head.w"], p["head.b"], "head", act=relu6)
    h = ctx.global_pool(h, "gap")
    h = ctx.dense(h, p["fc.w"], p["fc.b"], "fc")
    return h.a


def effnet_b0_s_init(rng):
    p = {}
    _conv_p(p, "stem", 3, 12, 3, rng)
    _irb_p(p, "b1", 12, 12, 1, 3, rng, se=True)
    _irb_p(p, "b2", 12, 18, 2, 4, rng, se=True)
    _irb_p(p, "b3", 18, 24, 2, 4, rng, se=True)
    _conv_p(p, "head", 24, 48, 1, rng)
    _dense_p(p, "fc", 48, ds.N_CLASSES, rng)
    return p


def effnet_b0_s_apply(ctx, p, x):
    h = ctx.input(x)
    h = ctx.conv(h, p["stem.w"], p["stem.b"], "stem", act=silu)
    h = _irb(ctx, p, h, "b1", 12, 12, 1, 3, silu, se=True,
             gain=_outlier_gain(36, hot=(2,), mag=24.0))
    h = _irb(ctx, p, h, "b2", 12, 18, 2, 4, silu, se=True,
             gain=_outlier_gain(48, hot=(3, 11), mag=24.0))
    h = _irb(ctx, p, h, "b3", 18, 24, 2, 4, silu, se=True)
    h = ctx.conv(h, p["head.w"], p["head.b"], "head", act=silu)
    h = ctx.global_pool(h, "gap")
    h = ctx.dense(h, p["fc.w"], p["fc.b"], "fc")
    return h.a


# ---------------------------------------------------------------------------
# transformers
# ---------------------------------------------------------------------------

def _tblock(ctx, p, x, name, d, heads, gain=None):
    """Pre-LN transformer block."""
    dh = d // heads

    h = ctx.layer_norm(x, p[f"{name}.ln1.g"], p[f"{name}.ln1.b"], f"{name}.ln1")
    q = ctx.dense(h, p[f"{name}.q.w"], p[f"{name}.q.b"], f"{name}.q")
    k = ctx.dense(h, p[f"{name}.k.w"], p[f"{name}.k.b"], f"{name}.k")
    v = ctx.dense(h, p[f"{name}.v.w"], p[f"{name}.v.b"], f"{name}.v")

    def split(t):
        b, s, _ = t.a.shape
        return QT(t.a.reshape(b, s, heads, dh).transpose(0, 2, 1, 3), t.src)

    att = ctx.softmax_attention(split(q), split(k), split(v), name,
                                scale=1.0 / np.sqrt(dh))
    b, hh, s, _ = att.a.shape
    att = QT(att.a.transpose(0, 2, 1, 3).reshape(b, s, d), att.src)
    o = ctx.dense(att, p[f"{name}.o.w"], p[f"{name}.o.b"], f"{name}.o")
    x = ctx.add(x, o, f"{name}.res1")

    h = ctx.layer_norm(x, p[f"{name}.ln2.g"], p[f"{name}.ln2.b"], f"{name}.ln2")
    h = ctx.dense(h, p[f"{name}.m1.w"], p[f"{name}.m1.b"], f"{name}.m1", act=gelu)
    if gain is not None:
        g = jnp.asarray(gain, jnp.float32)
        h = ctx.quant_act(h.a * g, f"{name}.amp.out")
    h = ctx.dense(h, p[f"{name}.m2.w"], p[f"{name}.m2.b"], f"{name}.m2")
    return ctx.add(x, h, f"{name}.res2")


def _tblock_p(p, name, d, mlp, rng):
    _ln_p(p, f"{name}.ln1", d)
    for nm in ("q", "k", "v", "o"):
        _dense_p(p, f"{name}.{nm}", d, d, rng)
    _ln_p(p, f"{name}.ln2", d)
    _dense_p(p, f"{name}.m1", d, mlp, rng)
    _dense_p(p, f"{name}.m2", mlp, d, rng)


VIT_D, VIT_HEADS, VIT_MLP = 48, 4, 96
BERT_D, BERT_HEADS, BERT_MLP = 48, 4, 96


def vit_s_init(rng):
    p = {}
    _conv_p(p, "patch", 3, VIT_D, 4, rng)
    p["pos"] = (rng.normal(size=(1, 16, VIT_D)) * 0.02).astype(np.float32)
    _tblock_p(p, "t1", VIT_D, VIT_MLP, rng)
    _tblock_p(p, "t2", VIT_D, VIT_MLP, rng)
    _ln_p(p, "lnf", VIT_D)
    _dense_p(p, "fc", VIT_D, ds.N_CLASSES, rng)
    return p


def vit_s_apply(ctx, p, x):
    h = ctx.input(x)
    h = ctx.conv(h, p["patch.w"], p["patch.b"], "patch", stride=4, padding="VALID")
    b, d, hh, ww = h.a.shape
    tok = QT(h.a.reshape(b, d, hh * ww).transpose(0, 2, 1), h.src)
    tok = ctx.quant_act(tok.a + p["pos"], "pos.out")
    tok = _tblock(ctx, p, tok, "t1", VIT_D, VIT_HEADS)
    tok = _tblock(ctx, p, tok, "t2", VIT_D, VIT_HEADS,
                  gain=_outlier_gain(VIT_MLP, hot=(5, 37), mag=18.0))
    tok = ctx.layer_norm(tok, p["lnf.g"], p["lnf.b"], "lnf")
    pooled = ctx.quant_act(tok.a.mean(1), "pool.out")
    out = ctx.dense(pooled, p["fc.w"], p["fc.b"], "fc")
    return out.a


def bert_s_init(rng, n_out=3):
    p = {}
    p["emb"] = (rng.normal(size=(ds.VOCAB, BERT_D)) * 0.5).astype(np.float32)
    p["pos"] = (rng.normal(size=(1, ds.SEQ_LEN, BERT_D)) * 0.02).astype(np.float32)
    _tblock_p(p, "t1", BERT_D, BERT_MLP, rng)
    _tblock_p(p, "t2", BERT_D, BERT_MLP, rng)
    _ln_p(p, "lnf", BERT_D)
    _dense_p(p, "fc", BERT_D, n_out, rng)
    return p


def bert_s_apply(ctx, p, tokens):
    """``tokens`` is i32[B, SEQ_LEN].  Embedding tables stay FP (gather, no
    MACs) — see DESIGN.md; their quantization is out of the paper's scope."""
    t = ctx.tokens(tokens)
    h = p["emb"][t.a] + p["pos"]
    h = ctx.quant_act(h, "emb.out")
    h = _tblock(ctx, p, h, "t1", BERT_D, BERT_HEADS,
                gain=_outlier_gain(BERT_MLP, hot=(9,), mag=16.0))
    h = _tblock(ctx, p, h, "t2", BERT_D, BERT_HEADS)
    h = ctx.layer_norm(h, p["lnf.g"], p["lnf.b"], "lnf")
    cls = ctx.quant_act(h.a[:, 0, :], "cls.out")
    out = ctx.dense(cls, p["fc.w"], p["fc.b"], "fc")
    return out.a


# ---------------------------------------------------------------------------
# segmentation
# ---------------------------------------------------------------------------

def deeplab_s_init(rng):
    p = _mnv3_trunk_p(rng)
    _conv_p(p, "aspp1", 24, 16, 1, rng)
    _conv_p(p, "aspp2", 24, 16, 3, rng)
    _conv_p(p, "fuse", 32, 16, 1, rng)
    _conv_p(p, "cls", 16, ds.SEG_CLASSES, 1, rng)
    return p


def deeplab_s_apply(ctx, p, x):
    h = _mnv3_trunk(ctx, p, x)  # B,24,4,4
    a1 = ctx.conv(h, p["aspp1.w"], p["aspp1.b"], "aspp1", act=relu)
    a2 = ctx.conv(h, p["aspp2.w"], p["aspp2.b"], "aspp2", act=relu)
    cat = ctx.concat([a1, a2], "aspp.cat")
    f = ctx.conv(cat, p["fuse.w"], p["fuse.b"], "fuse", act=relu)
    f = ctx.upsample2d(f, 4, "up")
    out = ctx.conv(f, p["cls.w"], p["cls.b"], "cls")
    return out.a


# ---------------------------------------------------------------------------
# mlp_parity_s — the PJRT ↔ sim-backend parity bridge
# ---------------------------------------------------------------------------
# A plain dense chain whose semantics the pure-Rust sim interpreter
# (rust/src/sim) reproduces exactly: input quantizer → [dense → relu →
# output quantizer]* → dense → logits quantizer, weights quantized
# per-output-channel (axis 1).  `sim::export_from_artifacts` re-exports this
# model's weights + data as a sim zoo and the artifacts-gated parity smoke
# test (rust/tests/sim_e2e.rs) asserts both backends agree on SQNR/metric
# to tolerance.  Keep the topology dense-only or the export will refuse it.


def mlp_parity_s_init(rng):
    p = {}
    _dense_p(p, "fc0", 3 * ds.IMG * ds.IMG, 32, rng)
    _dense_p(p, "fc1", 32, 24, rng)
    _dense_p(p, "fc2", 24, ds.N_CLASSES, rng)
    return p


def mlp_parity_s_apply(ctx, p, x):
    h = ctx.input(x)
    # flatten NCHW → [B, 3·IMG·IMG]: shape-only, reuses the input quantizer
    h = QT(h.a.reshape(h.a.shape[0], -1), h.src)
    h = ctx.dense(h, p["fc0.w"], p["fc0.b"], "fc0", act=relu)
    h = ctx.dense(h, p["fc1.w"], p["fc1.b"], "fc1", act=relu)
    h = ctx.dense(h, p["fc2.w"], p["fc2.b"], "fc2")
    return h.a


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BATCH = 32


def _img_example(batch=BATCH):
    return np.zeros((batch, 3, ds.IMG, ds.IMG), np.float32)


def _tok_example(batch=BATCH):
    return np.zeros((batch, ds.SEQ_LEN), np.int32)


class ModelDef:
    def __init__(self, name, task, init, apply, example, train_cfg):
        self.name = name
        self.task = task          # "classify10" | "seg" | "glue:<task>"
        self.init = init
        self.apply = apply        # apply(ctx, params, x) -> logits
        self.example = example    # () -> example input ndarray
        self.train_cfg = train_cfg  # dict(steps, lr, batch)


def _bert_def(task):
    n_out, _metric = ds.GLUE_TASKS[task]
    return ModelDef(
        f"bert_s_{task}",
        f"glue:{task}",
        lambda rng, n=n_out: bert_s_init(rng, n),
        bert_s_apply,
        _tok_example,
        dict(steps=700, lr=2e-3),
    )


MODELS = {
    "resnet_s": ModelDef("resnet_s", "classify10", resnet_s_init,
                         resnet_s_apply, _img_example, dict(steps=600, lr=2e-3)),
    "resnet_m": ModelDef("resnet_m", "classify10", resnet_m_init,
                         resnet_m_apply, _img_example, dict(steps=600, lr=2e-3)),
    "mobilenet_v2_s": ModelDef("mobilenet_v2_s", "classify10",
                               mobilenet_v2_s_init, mobilenet_v2_s_apply,
                               _img_example, dict(steps=700, lr=2e-3)),
    "mobilenet_v3_s": ModelDef("mobilenet_v3_s", "classify10",
                               mobilenet_v3_s_init, mobilenet_v3_s_apply,
                               _img_example, dict(steps=700, lr=2e-3)),
    "effnet_lite_s": ModelDef("effnet_lite_s", "classify10",
                              effnet_lite_s_init, effnet_lite_s_apply,
                              _img_example, dict(steps=700, lr=2e-3)),
    "effnet_b0_s": ModelDef("effnet_b0_s", "classify10",
                            effnet_b0_s_init, effnet_b0_s_apply,
                            _img_example, dict(steps=700, lr=2e-3)),
    "vit_s": ModelDef("vit_s", "classify10", vit_s_init, vit_s_apply,
                      _img_example, dict(steps=900, lr=1e-3)),
    "deeplab_s": ModelDef("deeplab_s", "seg", deeplab_s_init, deeplab_s_apply,
                          _img_example, dict(steps=700, lr=2e-3)),
    "mlp_parity_s": ModelDef("mlp_parity_s", "classify10", mlp_parity_s_init,
                             mlp_parity_s_apply, _img_example,
                             dict(steps=400, lr=2e-3)),
    **{f"bert_s_{t}": _bert_def(t) for t in ds.GLUE_TASKS},
}
