"""Build-time pretraining of the model zoo.

The paper starts from *converged pre-trained* networks; quantization
sensitivity is only meaningful on such networks.  Since no pretrained
checkpoints exist for our synthetic benchmarks, ``make artifacts`` trains
each zoo model to convergence here (seconds per model on CPU — the models
are miniatures) and freezes the weights into ``artifacts/``.

This file is build-path only; it is never lowered and never touches the
Rust runtime.  A hand-rolled Adam keeps the dependency set to jax+numpy.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as ds
from . import models as M
from .quantize import QCtx

TRAIN_N = 8192
VAL_N = 2048


def _loss_fn(task):
    if task == "classify10" or task.startswith("glue:"):
        gtask = task.split(":", 1)[1] if ":" in task else None
        if gtask == "stsb_s":
            def loss(logits, y):
                return jnp.mean((logits[:, 0] - y) ** 2)
            return loss

        def loss(logits, y):
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(
                logp, y.astype(jnp.int32)[:, None], axis=1))
        return loss
    if task == "seg":
        def loss(logits, y):
            # logits B,C,H,W ; y B,H,W
            logp = jax.nn.log_softmax(logits, axis=1)
            oh = jax.nn.one_hot(y, ds.SEG_CLASSES, axis=1)
            return -jnp.mean(jnp.sum(logp * oh, axis=1))
        return loss
    raise ValueError(task)


def task_data(task, split, n, seed=0):
    """Unified (x, y) loader for a ModelDef task string."""
    if task == "classify10":
        return ds.synthnet(split, n, seed)
    if task == "seg":
        return ds.synthseg(split, n, seed)
    if task.startswith("glue:"):
        return ds.synthglue(task.split(":", 1)[1], split, n, seed)
    raise ValueError(task)


def metric(task, logits, y):
    """Build-time metric (mirrored by rust/src/metrics at run time)."""
    logits = np.asarray(logits)
    y = np.asarray(y)
    if task == "classify10" or task.split(":")[-1] in ("rte_s", "sst2_s", "mnli_s"):
        return float((logits.argmax(-1) == y.astype(np.int64)).mean())
    if task.endswith("mrpc_s"):
        pred = logits.argmax(-1)
        yt = y.astype(np.int64)
        tp = float(((pred == 1) & (yt == 1)).sum())
        fp = float(((pred == 1) & (yt == 0)).sum())
        fn = float(((pred == 0) & (yt == 1)).sum())
        denom = 2 * tp + fp + fn
        return 2 * tp / denom if denom > 0 else 0.0
    if task.endswith("stsb_s"):
        p = logits[:, 0]
        pc = np.corrcoef(p, y)[0, 1]
        return float(0.0 if np.isnan(pc) else pc)
    if task == "seg":
        pred = logits.argmax(1)
        ious = []
        for c in range(ds.SEG_CLASSES):
            inter = float(((pred == c) & (y == c)).sum())
            union = float(((pred == c) | (y == c)).sum())
            if union > 0:
                ious.append(inter / union)
        return float(np.mean(ious))
    raise ValueError(task)


def _adam_init(params):
    z = {k: np.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: np.zeros_like(v) for k, v in params.items()}, "t": 0}


def eval_model(mdef: M.ModelDef, params, seed: int = 0):
    """FP32 validation metric for given weights (no training)."""
    names = list(params.keys())
    vx, vy = task_data(mdef.task, "val", VAL_N, seed)
    batch = M.BATCH
    plist = [jnp.asarray(params[k]) for k in names]
    apply_j = jax.jit(lambda pl, x: mdef.apply(QCtx(qparams=None),
                                               dict(zip(names, pl)), x))
    outs = []
    for i in range(0, len(vx) - batch + 1, batch):
        outs.append(np.asarray(apply_j(plist, jnp.asarray(vx[i:i + batch]))))
    logits = np.concatenate(outs)
    return metric(mdef.task, logits, vy[: len(logits)])


def train_model(mdef: M.ModelDef, seed: int = 0, verbose: bool = True):
    """Train one zoo model; returns (params, fp32_val_metric)."""
    rng = np.random.default_rng(seed + 17)
    params = mdef.init(rng)
    loss_fn = _loss_fn(mdef.task)
    names = list(params.keys())

    def fwd_loss(plist, x, y):
        p = dict(zip(names, plist))
        ctx = QCtx(qparams=None)
        logits = mdef.apply(ctx, p, x)
        return loss_fn(logits, y)

    grad_fn = jax.jit(jax.value_and_grad(lambda pl, x, y: fwd_loss(pl, x, y)))

    xs, ys = task_data(mdef.task, "train", TRAIN_N, seed)
    vx, vy = task_data(mdef.task, "val", VAL_N, seed)
    cfg = mdef.train_cfg
    lr, steps, batch = cfg["lr"], cfg["steps"], M.BATCH
    opt = _adam_init(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    plist = [jnp.asarray(params[k]) for k in names]

    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, len(xs), size=batch)
        loss, grads = grad_fn(plist, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        opt["t"] += 1
        t = opt["t"]
        new = []
        for k, pv, g in zip(names, plist, grads):
            m = opt["m"][k] = b1 * opt["m"][k] + (1 - b1) * np.asarray(g)
            v = opt["v"][k] = b2 * opt["v"][k] + (1 - b2) * np.asarray(g) ** 2
            mh = m / (1 - b1**t)
            vh = v / (1 - b2**t)
            new.append(pv - lr * mh / (np.sqrt(vh) + eps))
        plist = [jnp.asarray(p) for p in new]
        if verbose and (step % 200 == 0 or step == steps - 1):
            print(f"  [{mdef.name}] step {step:4d} loss {float(loss):.4f}", flush=True)

    params = {k: np.asarray(v, np.float32) for k, v in zip(names, plist)}

    # fp32 validation metric
    apply_j = jax.jit(lambda pl, x: mdef.apply(QCtx(qparams=None),
                                               dict(zip(names, pl)), x))
    outs = []
    for i in range(0, len(vx), batch):
        xb = vx[i:i + batch]
        if len(xb) < batch:  # pad tail to static batch
            pad = batch - len(xb)
            xb = np.concatenate([xb, xb[:pad]])
            outs.append(np.asarray(apply_j(plist, jnp.asarray(xb)))[: batch - pad])
        else:
            outs.append(np.asarray(apply_j(plist, jnp.asarray(xb))))
    logits = np.concatenate(outs)
    m = metric(mdef.task, logits, vy)
    if verbose:
        print(f"  [{mdef.name}] trained in {time.time()-t0:.1f}s, "
              f"fp32 val metric = {m:.4f}", flush=True)
    return params, m
