//! Efficiency-budget scenario (paper §3.3.1): "give me the most accurate
//! network that costs at most r·BOPs(W8A16)".
//!
//!     cargo run --release --example bops_budget -- --model mobilenet_v3_s --budget 0.4
//!
//! Sweeps a few budgets to show the accuracy/efficiency pareto the greedy
//! flip search walks, and prints the final per-group kernel selection —
//! exactly what a deployment pipeline would hand to the compiler.

use mpq::coordinator::Pipeline;
use mpq::groups::Lattice;
use mpq::Result;

fn main() -> Result<()> {
    let args = mpq::cli::Args::from_env()?;
    let model = args.opt_str("model", "mobilenet_v3_s");
    let budget = args.opt_f64("budget", 0.4)?;
    let mut pipe = Pipeline::open(mpq::artifacts_dir(), model)?;
    pipe.calibrate(args.opt_usize("calib", 256)?, args.opt_u64("seed", 0)?)?;

    let lat = Lattice::practical();
    let fp = pipe.eval_fp32()?;
    println!("{model}: fp32 = {fp:.4}");

    let sens = pipe.sensitivity_sqnr(&lat)?;
    let flips = pipe.flips(&lat, &sens);
    for b in [0.75, 0.5, budget] {
        let run = pipe.search_bops_budget(&lat, &flips, b)?;
        println!(
            "budget r ≤ {b:.3}: achieved r = {:.3}, metric = {:.4} ({} flips)",
            run.final_rel_bops,
            run.final_metric,
            run.applied.len()
        );
    }

    let run = pipe.search_bops_budget(&lat, &flips, budget)?;
    println!("\nfinal kernel selection at r = {:.3}:", run.final_rel_bops);
    for (g, cand) in run.assignment.per_group.iter().enumerate() {
        let grp = &pipe.model.entry.groups[g];
        if grp.macs == 0 {
            continue;
        }
        let names: Vec<&str> = grp
            .w_q
            .iter()
            .map(|&w| pipe.model.entry.w_quantizers[w].name.as_str())
            .collect();
        println!("  {:<9} {:>10} MACs  {}", cand.label(), grp.macs, names.join(", "));
    }
    Ok(())
}
