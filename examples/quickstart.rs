//! Quickstart: the paper's two-phase algorithm end to end on one model.
//!
//!     cargo run --release --example quickstart [-- --model resnet_s]
//!
//! Phase 1 builds the SQNR sensitivity list from 256 unlabeled calibration
//! images; Phase 2 greedily flips quantizer groups to meet a BOPs budget
//! (r ≤ 0.5, i.e. the W8A8-equivalent cost), then reports the mixed network
//! against FP32 and the fixed-precision baselines.

use mpq::coordinator::Pipeline;
use mpq::groups::{Candidate, Lattice};
use mpq::Result;

fn main() -> Result<()> {
    let args = mpq::cli::Args::from_env()?;
    let model = args.opt_str("model", "resnet_s");
    let dir = mpq::artifacts_dir();

    println!("== mpq quickstart: {model} ==");
    let mut pipe = Pipeline::open(&dir, model)?;
    println!("platform: {}", pipe.rt.platform());
    println!(
        "quantizers: {} act, {} w, {} groups, {:.1} MMACs",
        pipe.model.entry.n_act(),
        pipe.model.entry.n_w(),
        pipe.model.entry.groups.len(),
        pipe.model.entry.total_macs as f64 / 1e6
    );

    // Phase 0: calibrate ranges on 256 unlabeled images (MSE criteria)
    pipe.calibrate(256, 0)?;

    let fp32 = pipe.eval_fp32()?;
    println!("fp32 val metric:  {fp32:.4} (manifest: {:.4})", pipe.model.entry.fp32_val_metric);

    let lat = Lattice::practical();
    for cand in [Candidate::new(8, 8), Candidate::new(4, 8)] {
        let m = pipe.eval_fixed(cand, None)?;
        println!("fixed {}:      {m:.4}", cand.label());
    }

    // Phase 1: SQNR sensitivity list
    let sens = pipe.sensitivity_sqnr(&lat)?;
    println!("\nphase 1: {} (group, candidate) probes; top-5 least sensitive:", sens.len());
    for e in sens.iter().take(5) {
        println!(
            "  group {:>2} → {}  Ω = {:.1} dB",
            e.group,
            e.cand.label(),
            e.score
        );
    }

    // Phase 2: greedy pareto flips to a BOPs budget
    let flips = pipe.flips(&lat, &sens);
    let run = pipe.search_bops_budget(&lat, &flips, 0.5)?;
    println!(
        "\nphase 2: {} flips applied → r = {:.3}, val metric = {:.4}",
        run.applied.len(),
        run.final_rel_bops,
        run.final_metric
    );
    println!("(fixed W8A8 is r = 0.500 — the mixed model should match or beat it)");
    Ok(())
}
