//! Task-performance budget scenario (paper §3.3.2 + §3.6): "I can tolerate
//! at most X points of accuracy drop — find the cheapest network", solved
//! with the three Phase-2 schemes of Table 5 so their run-time/eval-count
//! trade-off is visible.
//!
//!     cargo run --release --example accuracy_target -- --model resnet_m --drop 0.01

use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::groups::Lattice;
use mpq::Result;

fn main() -> Result<()> {
    let args = mpq::cli::Args::from_env()?;
    let model = args.opt_str("model", "resnet_m");
    let drop = args.opt_f64("drop", 0.01)?;
    let mut pipe = Pipeline::open(mpq::artifacts_dir(), model)?;
    pipe.calibrate(args.opt_usize("calib", 256)?, 0)?;

    let lat = Lattice::practical();
    let fp = pipe.eval_fp32()?;
    let target = fp - drop;
    println!("{model}: fp32 = {fp:.4}, target ≥ {target:.4} (-{:.1} pts)", drop * 100.0);

    let sens = pipe.sensitivity_sqnr(&lat)?;
    let flips = pipe.flips(&lat, &sens);
    println!("flip sequence: {} candidate steps", flips.len());

    for scheme in [SearchScheme::Sequential, SearchScheme::Binary, SearchScheme::Hybrid] {
        let run = pipe.search_accuracy_target(&lat, &flips, target, scheme, None)?;
        println!(
            "{:<14} r = {:.3}  metric = {:.4}  evals = {:<3} wall = {:.2}s",
            scheme.label(),
            run.final_rel_bops,
            run.final_metric,
            run.evals,
            run.wall_secs
        );
    }
    Ok(())
}
