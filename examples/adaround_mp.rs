//! AdaRound-integrated mixed precision (paper §3.5): learn per-layer weight
//! rounding once per bit-width, interweave it into Phase 1, stitch the
//! rounded weights per configuration in Phase 2.
//!
//!     cargo run --release --example adaround_mp -- --model mobilenet_v2_s

use mpq::adaround::AdaRoundCfg;
use mpq::coordinator::Pipeline;
use mpq::groups::{Candidate, Lattice};
use mpq::sensitivity::Metric;
use mpq::Result;

fn main() -> Result<()> {
    let args = mpq::cli::Args::from_env()?;
    let model = args.opt_str("model", "mobilenet_v2_s");
    let mut pipe = Pipeline::open(mpq::artifacts_dir(), model)?;
    pipe.calibrate(args.opt_usize("calib", 256)?, 0)?;

    let lat = Lattice::practical();
    let mut cfg = AdaRoundCfg::default();
    cfg.steps = args.opt_usize("steps", cfg.steps)?;

    println!("{model}: AdaRounding {} layers × {:?} bit options ({} steps each)…",
             pipe.model.entry.adaround.len(), lat.wbits_options(), cfg.steps);
    let t = mpq::util::Timer::start();
    let rounded = pipe.adaround(&lat, &cfg)?;
    println!("…done in {:.1}s ({} rounded tensors)", t.secs(), rounded.len());

    let fp = pipe.eval_fp32()?;
    let w4a8_plain = pipe.eval_fixed(Candidate::new(4, 8), None)?;
    let w4a8_ar = pipe.eval_fixed(Candidate::new(4, 8), Some(&rounded))?;
    println!("fp32 {fp:.4} | fixed W4A8 nearest {w4a8_plain:.4} | fixed W4A8 AdaRound {w4a8_ar:.4}");

    // interweaved MP at r=0.375
    let sens = pipe.sensitivity(&lat, Metric::Sqnr, Some(&rounded))?;
    let flips = pipe.flips(&lat, &sens);
    let run = pipe.search_bops_budget(&lat, &flips, 0.375)?;
    let m_ar = pipe.eval_assignment(&run.assignment, Some(&rounded))?;
    println!("AdaRound MP @ r={:.3}: {m_ar:.4}", run.final_rel_bops);
    Ok(())
}
