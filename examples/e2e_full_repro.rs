//! End-to-end validation driver (the EXPERIMENTS.md run).
//!
//! Exercises every layer of the stack on a real small workload:
//!
//! 1. loads AOT artifacts (L1 Pallas kernels inside L2 JAX graphs) through
//!    the PJRT runtime,
//! 2. verifies the Rust FP32 evaluation matches the number the python
//!    build path recorded in the manifest (cross-layer numerical check),
//! 3. runs the paper's full two-phase algorithm (SQNR Phase 1, greedy
//!    Phase 2) on every model in the manifest under the practical lattice,
//! 4. runs one accuracy-target search with all three schemes on one model,
//! 5. reports a summary table and writes results/e2e.{txt,csv}.
//!
//!     cargo run --release --example e2e_full_repro [-- --models a,b --fast]

use mpq::coordinator::{Pipeline, SearchScheme};
use mpq::groups::{Candidate, Lattice};
use mpq::manifest::Manifest;
use mpq::report::{f3, f4, Table};
use mpq::runtime::Runtime;
use mpq::Result;
use std::rc::Rc;

fn main() -> Result<()> {
    let args = mpq::cli::Args::from_env()?;
    let dir = mpq::artifacts_dir();
    let man = Manifest::load(&dir)?;
    let rt = Rc::new(Runtime::for_manifest(&man)?);
    let calib_n = args.opt_usize("calib", 256)?;
    let filter: Option<Vec<String>> =
        args.opt("models").map(|s| s.split(',').map(String::from).collect());

    let mut t = Table::new(
        "e2e: two-phase MPQ across the zoo (practical lattice)",
        &["Model", "FP32 (manifest)", "FP32 (rust)", "W8A8", "MP r", "MP metric", "Δ vs W8A8"],
    );
    let lat = Lattice::practical();
    let total = mpq::util::Timer::start();
    let mut fp_mismatch = 0;

    let names: Vec<String> = man
        .models
        .iter()
        .map(|m| m.name.clone())
        .filter(|n| filter.as_ref().map(|f| f.contains(n)).unwrap_or(true))
        .collect();
    for name in &names {
        let step = mpq::util::Timer::start();
        let mut pipe = Pipeline::open_with(rt.clone(), &man, name)?;
        pipe.calibrate(calib_n, 0)?;
        let fp = pipe.eval_fp32()?;
        let want = pipe.model.entry.fp32_val_metric;
        // cross-layer check: python (jax) and rust (PJRT) must agree
        if (fp - want).abs() > 5e-3 {
            eprintln!("WARN {name}: rust fp32 {fp:.4} != manifest {want:.4}");
            fp_mismatch += 1;
        }
        let w8a8 = pipe.eval_fixed(Candidate::new(8, 8), None)?;
        let sens = pipe.sensitivity_sqnr(&lat)?;
        let flips = pipe.flips(&lat, &sens);
        let run = pipe.search_bops_budget(&lat, &flips, 0.5)?;
        t.row(vec![
            name.clone(),
            f4(want),
            f4(fp),
            f4(w8a8),
            f3(run.final_rel_bops),
            f4(run.final_metric),
            format!("{:+.4}", run.final_metric - w8a8),
        ]);
        println!(
            "[e2e] {name}: fp32 {fp:.4}, MP(r={:.3}) {:.4} vs W8A8 {:.4}  ({:.0}s)",
            run.final_rel_bops,
            run.final_metric,
            w8a8,
            step.secs()
        );
    }
    t.print();
    t.save(mpq::report::results_dir(), "e2e")?;

    // accuracy-target search, all three schemes (Table 5 shape)
    if let Some(m) = names.iter().find(|n| n.as_str() == "mobilenet_v2_s") {
        let mut pipe = Pipeline::open_with(rt.clone(), &man, m)?;
        pipe.calibrate(calib_n, 0)?;
        let fp = pipe.eval_fp32()?;
        let sens = pipe.sensitivity_sqnr(&lat)?;
        let flips = pipe.flips(&lat, &sens);
        println!("\naccuracy-target search on {m} (target = fp32 − 1pt):");
        for scheme in [SearchScheme::Sequential, SearchScheme::Binary, SearchScheme::Hybrid] {
            let run = pipe.search_accuracy_target(&lat, &flips, fp - 0.01, scheme, None)?;
            println!(
                "  {:<14} r={:.3} metric={:.4} evals={} wall={:.2}s",
                scheme.label(),
                run.final_rel_bops,
                run.final_metric,
                run.evals,
                run.wall_secs
            );
        }
    }

    println!(
        "\ne2e complete: {} models, {} fp32 mismatches, {} executables compiled, {:.0}s total",
        names.len(),
        fp_mismatch,
        rt.compiled_count(),
        total.secs()
    );
    if fp_mismatch > 0 {
        anyhow::bail!("{fp_mismatch} cross-layer fp32 mismatches");
    }
    Ok(())
}
